"""DeepSeek-V2 (236B): Multi-head Latent Attention + fine-grained MoE.

MLA (arXiv:2405.04434): queries go through a low-rank bottleneck
(q_lora_rank); keys/values are reconstructed from a shared compressed latent
c_kv (kv_lora_rank = 512) plus a single shared 64-dim RoPE key.  The decode
path uses the *absorbed* formulation — attention runs directly against the
latent cache (576 floats/token), which is what qualifies this arch for the
long_500k decode shape: per-step cost is O(T * kv_lora), cache is
O(T * 576), no per-head K/V ever materialized.

MoE: layer 0 is a dense SwiGLU FFN (paper's warm layer); layers 1..L-1 use
2 shared experts + 160 routed experts with top-6 routing (moe.moe_apply).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common, moe
from repro.models.common import Param
from repro.sharding.context import constrain

__all__ = [
    "DeepSeekConfig",
    "schema",
    "init",
    "forward",
    "init_cache",
    "decode_step",
]


@dataclasses.dataclass(frozen=True)
class DeepSeekConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff_expert: int            # routed-expert hidden (1536)
    d_ff_dense: int             # layer-0 dense hidden
    vocab: int
    n_experts: int = 160
    top_k: int = 6
    n_shared_experts: int = 2
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0
    capacity_factor: float = 1.25
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    kv_chunk: int = 2048

    @property
    def family(self) -> str:
        return "moe"

    @property
    def qk_dim(self) -> int:
        return self.qk_nope_dim + self.qk_rope_dim

    @property
    def moe(self) -> moe.MoEConfig:
        return moe.MoEConfig(
            n_experts=self.n_experts,
            top_k=self.top_k,
            d_model=self.d_model,
            d_ff=self.d_ff_expert,
            capacity_factor=self.capacity_factor,
            n_shared_experts=self.n_shared_experts,
            d_ff_shared=self.n_shared_experts * self.d_ff_expert,
        )


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------


def mla_schema(cfg: DeepSeekConfig) -> Dict[str, Any]:
    d, h = cfg.d_model, cfg.n_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "w_dq": Param((d, qr), ("embed", None)),
        "q_norm": Param((qr,), (None,), init="ones"),
        "w_uq": Param((qr, h, dn + dr), (None, "heads", None)),
        "w_dkv": Param((d, kr), ("embed", None)),
        "kv_norm": Param((kr,), (None,), init="ones"),
        "w_kr": Param((d, dr), ("embed", None)),
        "w_uk": Param((kr, h, dn), (None, "heads", None)),
        "w_uv": Param((kr, h, dv), (None, "heads", None)),
        "wo": Param((h, dv, d), ("heads", None, "embed")),
    }


def layer_schema(cfg: DeepSeekConfig, *, dense: bool) -> Dict[str, Any]:
    d = cfg.d_model
    s: Dict[str, Any] = {
        "attn": mla_schema(cfg),
        "attn_norm": Param((d,), (None,), init="ones"),
        "mlp_norm": Param((d,), (None,), init="ones"),
    }
    if dense:
        s["mlp"] = {
            "w_gate": Param((d, cfg.d_ff_dense), ("embed", "ff")),
            "w_up": Param((d, cfg.d_ff_dense), ("embed", "ff")),
            "w_down": Param((cfg.d_ff_dense, d), ("ff", "embed")),
        }
    else:
        s["moe"] = moe.moe_layer_schema(cfg.moe)
    return s


def schema(cfg: DeepSeekConfig) -> Dict[str, Any]:
    return {
        "embed": Param((cfg.vocab, cfg.d_model), ("vocab", None), init="embed"),
        "dense_layer": layer_schema(cfg, dense=True),
        "layers": common.stacked(layer_schema(cfg, dense=False), cfg.n_layers - 1),
        "final_norm": Param((cfg.d_model,), (None,), init="ones"),
        "lm_head": Param((cfg.d_model, cfg.vocab), ("embed", "vocab")),
    }


def init(rng: jax.Array, cfg: DeepSeekConfig):
    return common.init_from_schema(rng, schema(cfg), cfg.param_dtype)


# ---------------------------------------------------------------------------
# MLA attention
# ---------------------------------------------------------------------------


def _mla_qkv_full(ap: Dict[str, Any], x: jax.Array, positions: jax.Array, cfg: DeepSeekConfig):
    """Full-sequence MLA: materialize per-head K/V from the latent."""
    q_lat = common.rms_norm(jnp.einsum("bsd,dq->bsq", x, ap["w_dq"]), ap["q_norm"])
    q = jnp.einsum("bsq,qhk->bshk", q_lat, ap["w_uq"])
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim :]
    q_rope = common.apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = common.rms_norm(jnp.einsum("bsd,dc->bsc", x, ap["w_dkv"]), ap["kv_norm"])
    k_rope = jnp.einsum("bsd,dr->bsr", x, ap["w_kr"])[:, :, None, :]  # (B,S,1,dr)
    k_rope = common.apply_rope(k_rope, positions, cfg.rope_theta)
    k_nope = jnp.einsum("bsc,chk->bshk", c_kv, ap["w_uk"])
    v = jnp.einsum("bsc,chk->bshk", c_kv, ap["w_uv"])

    h = cfg.n_heads
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_rope.shape[:2] + (h, cfg.qk_rope_dim))],
        axis=-1,
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    return q_full, k, v, c_kv, k_rope


def _mla_attention_full(ap, x, positions, cfg: DeepSeekConfig):
    q, k, v, _, _ = _mla_qkv_full(ap, x, positions, cfg)
    scale = 1.0 / math.sqrt(cfg.qk_dim)
    attn = common.full_attention(
        q, k, v, causal=True, kv_chunk=cfg.kv_chunk, softmax_scale=scale
    )
    return jnp.einsum("bshk,hkd->bsd", attn, ap["wo"])


def _mla_attention_absorbed(
    ap: Dict[str, Any],
    x: jax.Array,
    c_cache: jax.Array,
    kr_cache: jax.Array,
    pos: jax.Array,
    cfg: DeepSeekConfig,
):
    """Absorbed decode: score and combine directly in latent space.

    c_cache: (B, T, kv_lora); kr_cache: (B, T, rope_dim); x: (B, 1, d).
    Returns (attn_out (B,1,d), updated caches).
    """
    positions = jnp.full((1,), pos, jnp.int32)
    q_lat = common.rms_norm(jnp.einsum("bsd,dq->bsq", x, ap["w_dq"]), ap["q_norm"])
    q = jnp.einsum("bsq,qhk->bshk", q_lat, ap["w_uq"])  # (B,1,H,dn+dr)
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim :]
    q_rope = common.apply_rope(q_rope, positions, cfg.rope_theta)

    c_new = common.rms_norm(jnp.einsum("bsd,dc->bsc", x, ap["w_dkv"]), ap["kv_norm"])
    kr_new = common.apply_rope(
        jnp.einsum("bsd,dr->bsr", x, ap["w_kr"])[:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0, :]
    c_cache = jax.lax.dynamic_update_slice_in_dim(c_cache, c_new, pos, axis=1)
    kr_cache = jax.lax.dynamic_update_slice_in_dim(kr_cache, kr_new, pos, axis=1)
    # Keep the latent cache sequence-sharded through the scan (otherwise the
    # absorbed-attention einsums run against a replicated 500k-token cache).
    c_cache = constrain(c_cache, ("batch", "cache_seq", None))
    kr_cache = constrain(kr_cache, ("batch", "cache_seq", None))

    # Absorb W_uk into the query: q_eff (B,H,kv_lora).
    q_eff = jnp.einsum("bshk,chk->bhc", q_nope, ap["w_uk"])
    scores = jnp.einsum(
        "bhc,btc->bht", q_eff, c_cache, preferred_element_type=jnp.float32
    )
    scores = scores + jnp.einsum(
        "bshr,btr->bht", q_rope, kr_cache, preferred_element_type=jnp.float32
    )
    scores = scores / math.sqrt(cfg.qk_dim)
    t = c_cache.shape[1]
    mask = jnp.arange(t) <= pos
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bht,btc->bhc", probs.astype(c_cache.dtype), c_cache)
    out = jnp.einsum("bhc,chk->bhk", out_lat, ap["w_uv"])  # (B,H,v_dim)
    attn = jnp.einsum("bhk,hkd->bd", out, ap["wo"])[:, None, :]
    return attn, c_cache, kr_cache


# ---------------------------------------------------------------------------
# Forward / decode
# ---------------------------------------------------------------------------


def _dense_mlp(lp, x):
    g = jnp.einsum("bsd,df->bsf", x, lp["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, lp["w_up"])
    return jnp.einsum("bsf,fd->bsd", common.swiglu(g, u), lp["w_down"])


def forward(
    params: Dict[str, Any], cfg: DeepSeekConfig, tokens: jax.Array
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x = common.constrain(x, ("batch", None, None))
    positions = jnp.arange(s)

    # Layer 0: dense FFN.
    lp0 = params["dense_layer"]
    h = common.rms_norm(x, lp0["attn_norm"])
    x = x + _mla_attention_full(lp0["attn"], h, positions, cfg)
    h = common.rms_norm(x, lp0["mlp_norm"])
    x = x + _dense_mlp(lp0["mlp"], h)

    def body(x, lp):
        h = common.rms_norm(x, lp["attn_norm"])
        x = x + _mla_attention_full(lp["attn"], h, positions, cfg)
        h = common.rms_norm(x, lp["mlp_norm"])
        out, stats = moe.moe_apply(lp["moe"], h, cfg.moe)
        return x + out, stats

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, stats = jax.lax.scan(body_fn, x, params["layers"])
    x = common.rms_norm(x, params["final_norm"])
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["lm_head"].astype(cfg.compute_dtype)
    ).astype(jnp.float32)
    return logits, {k: v.mean() for k, v in stats.items()}


def init_cache(cfg: DeepSeekConfig, batch: int, seq_len: int, dtype=None):
    """Latent cache: 512 + 64 floats per token per layer."""
    if dtype is None:
        dtype = cfg.compute_dtype  # cache dtype must match decode K/V
    return {
        "c": jnp.zeros((cfg.n_layers, batch, seq_len, cfg.kv_lora_rank), dtype),
        "kr": jnp.zeros((cfg.n_layers, batch, seq_len, cfg.qk_rope_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(
    params: Dict[str, Any],
    cfg: DeepSeekConfig,
    cache: Dict[str, jax.Array],
    tokens: jax.Array,
    pos: jax.Array,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)

    # Layer 0 (dense) — its cache slice is index 0.
    lp0 = params["dense_layer"]
    h = common.rms_norm(x, lp0["attn_norm"])
    attn, c0, kr0 = _mla_attention_absorbed(
        lp0["attn"], h, cache["c"][0], cache["kr"][0], pos, cfg
    )
    x = x + attn
    h = common.rms_norm(x, lp0["mlp_norm"])
    x = x + _dense_mlp(lp0["mlp"], h)

    def body(x, layer):
        lp, c_l, kr_l = layer
        h = common.rms_norm(x, lp["attn_norm"])
        attn, c_l, kr_l = _mla_attention_absorbed(lp["attn"], h, c_l, kr_l, pos, cfg)
        x = x + attn
        h = common.rms_norm(x, lp["mlp_norm"])
        out, _ = moe.moe_apply(lp["moe"], h, cfg.moe)
        return x + out, (c_l, kr_l)

    x, (c_rest, kr_rest) = jax.lax.scan(
        body, x, (params["layers"], cache["c"][1:], cache["kr"][1:])
    )
    x = common.rms_norm(x, params["final_norm"])
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["lm_head"].astype(cfg.compute_dtype)
    ).astype(jnp.float32)
    new_cache = {
        "c": jnp.concatenate([c0[None], c_rest], axis=0),
        "kr": jnp.concatenate([kr0[None], kr_rest], axis=0),
        "pos": pos + 1,
    }
    return logits, new_cache

"""chameleon-34b [vlm] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536, early-fusion, VQ image tokens [arXiv:2405.09818].

Early fusion means the backbone is a plain token transformer over a unified
text + VQ-image-code vocabulary; the VQ image tokenizer is the stub per the
assignment (tokens arrive pre-quantized).  QK-norm per the source paper.
"""
from repro.models.dense import DenseConfig

ARCH_ID = "chameleon-34b"


def config() -> DenseConfig:
    return DenseConfig(
        name=ARCH_ID,
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab=65536,
        head_dim=128,
        rope_theta=10000.0,
        act="swiglu",
        norm="rmsnorm",
        qk_norm=True,
        decode_window=8192,
    )


def reduced() -> DenseConfig:
    return DenseConfig(
        name=ARCH_ID + "-reduced",
        n_layers=2,
        d_model=256,
        n_heads=8,
        n_kv_heads=2,
        d_ff=512,
        vocab=512,
        head_dim=32,
        qk_norm=True,
        decode_window=64,
        remat=False,
    )

"""Oracle: the step-by-step selective scan from models/hymba.py."""
from repro.models.hymba import selective_scan_ref


def ssm_ref(u, dt, b_t, c_t, log_a):
    """u/dt: (B,T,D); b_t/c_t: (B,T,N); log_a: (D,N) -> (y, h_final)."""
    return selective_scan_ref(u, dt, log_a, b_t, c_t)

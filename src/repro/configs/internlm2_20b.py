"""internlm2-20b [dense] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 [arXiv:2403.17297]."""
from repro.models.dense import DenseConfig

ARCH_ID = "internlm2-20b"


def config() -> DenseConfig:
    return DenseConfig(
        name=ARCH_ID,
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab=92544,
        head_dim=128,
        rope_theta=1000000.0,
        act="swiglu",
        norm="rmsnorm",
        decode_window=8192,
    )


def reduced() -> DenseConfig:
    return DenseConfig(
        name=ARCH_ID + "-reduced",
        n_layers=2,
        d_model=192,
        n_heads=6,
        n_kv_heads=2,
        d_ff=384,
        vocab=512,
        head_dim=32,
        decode_window=64,
        remat=False,
    )

"""Batched OptPerf engine: seeded (hypothesis-free) equivalence against the
scalar water-fill and Algorithm-1 oracles, water-fill finalization
invariants, integer-rounding hardening, and sweep-consumer plan parity."""
import numpy as np
import pytest

from repro.core.controller import CannikinController
from repro.core.goodput import BatchSizeSelector, goodput, goodput_curve
from repro.core.optperf import (
    round_batches,
    solve_optperf_algorithm1,
    solve_optperf_batch,
    solve_optperf_waterfill,
)
from repro.core.perf_model import ClusterPerfModel, CommModel, NodePerfModel
from repro.core.simulator import SimulatedCluster, cluster_B


def random_model(rng: np.random.Generator, n: int) -> ClusterPerfModel:
    """Random cluster spanning compute-, comm-, and mixed-bottleneck regimes
    (t_o drawn across three orders of magnitude drives the regime)."""
    nodes = tuple(
        NodePerfModel(
            q=float(rng.uniform(1e-4, 8e-3)),
            s=float(rng.uniform(0.0, 0.02)),
            k=float(rng.uniform(1e-4, 8e-3)),
            m=float(rng.uniform(0.0, 0.02)),
        )
        for _ in range(n)
    )
    comm = CommModel(
        t_o=float(10.0 ** rng.uniform(-4, -1)),
        t_u=float(rng.uniform(0.0, 0.02)),
        gamma=float(rng.uniform(0.02, 0.6)),
    )
    return ClusterPerfModel(nodes=nodes, comm=comm)


# 200 random clusters: 50 per cluster size.
CASES = [(n, seed) for n in (2, 16, 64, 256) for seed in range(50)]


@pytest.mark.parametrize("n,seed", CASES, ids=lambda v: str(v))
def test_batch_matches_scalar_oracles(n, seed):
    """`solve_optperf_batch` == scalar water-fill == Algorithm 1 within 1e-6
    relative opt_perf, and partitions sum exactly to each candidate."""
    rng = np.random.default_rng(1000 * n + seed)
    model = random_model(rng, n)
    cands = np.unique(np.round(rng.uniform(8, 8192, size=5))).astype(np.float64)
    batch = solve_optperf_batch(model, cands)
    for j, b in enumerate(cands):
        wf = solve_optperf_waterfill(model, float(b))
        a1 = solve_optperf_algorithm1(model, float(b))
        assert batch.opt_perfs[j] == pytest.approx(wf.opt_perf, rel=1e-6)
        assert batch.opt_perfs[j] == pytest.approx(a1.opt_perf, rel=1e-6)
        assert batch.batches[j].sum() == pytest.approx(b, rel=1e-9)
        assert batch.batches[j].min() >= 0.0
        # Realized time equals the reported optimum.
        assert model.cluster_time(list(batch.batches[j])) == pytest.approx(
            float(batch.opt_perfs[j]), rel=1e-12
        )


def test_batch_solution_extraction_roundtrip():
    rng = np.random.default_rng(7)
    model = random_model(rng, 5)
    batch = solve_optperf_batch(model, [64.0, 256.0, 1024.0])
    assert len(batch) == 3
    sol = batch.solution(1)
    assert sol.total_batch == 256.0
    assert sum(sol.batches) == pytest.approx(256.0, rel=1e-9)
    assert sol.bottleneck == batch.bottleneck(1)
    assert len(batch.solutions()) == 3


def test_batch_input_validation():
    rng = np.random.default_rng(3)
    model = random_model(rng, 3)
    with pytest.raises(ValueError):
        solve_optperf_batch(model, [])
    with pytest.raises(ValueError):
        solve_optperf_batch(model, [128.0, -1.0])
    with pytest.raises(ValueError):
        solve_optperf_batch(model, [[128.0]])
    with pytest.raises(ValueError):
        BatchSizeSelector(candidates=(64,), ref_batch=64, engine="bathced")


def test_batch_solution_does_not_alias_caller_array():
    rng = np.random.default_rng(9)
    model = random_model(rng, 4)
    cands = np.array([64.0, 256.0])
    sol = solve_optperf_batch(model, cands)
    cands[0] = 1e9  # caller reuses its buffer
    assert sol.total_batches[0] == 64.0
    with pytest.raises(ValueError):
        sol.batches[0, 0] = 0.0  # result arrays are frozen


def test_waterfill_positive_nodes_respect_time_bound():
    """Finalization never inflates a binding node past the bisected bound:
    every positive-batch node's realized time is <= the reported optimum
    (clamped stragglers may sit above it at their fixed floor)."""
    for seed in range(30):
        rng = np.random.default_rng(seed)
        model = random_model(rng, int(rng.integers(2, 32)))
        sol = solve_optperf_waterfill(model, float(rng.uniform(4, 4096)))
        times = model.node_times(np.asarray(sol.batches))
        positive = np.asarray(sol.batches) > 0
        assert np.all(times[positive] <= sol.opt_perf * (1 + 1e-8))
        assert sum(sol.batches) == pytest.approx(sol.total_batch, rel=1e-9)


def test_waterfill_clamps_hopeless_straggler():
    model = ClusterPerfModel(
        nodes=(
            NodePerfModel(q=1e-4, s=0.0, k=1e-4, m=0.0),
            NodePerfModel(q=1.0, s=10.0, k=1.0, m=10.0),
        ),
        comm=CommModel(t_o=0.001, t_u=0.001, gamma=0.1),
    )
    batch = solve_optperf_batch(model, [64.0, 128.0])
    assert batch.batches[0, 1] == 0.0
    assert batch.batches[0, 0] == pytest.approx(64.0)
    assert batch.batches[1, 0] == pytest.approx(128.0)


def test_round_batches_negative_float_residue():
    """Floors already overshooting the total (post-rescale float residue) are
    handled by decrementing the smallest fractional parts, not by raising."""
    out = round_batches([11.0, 11.0, 10.000001], 31)
    assert sum(out) == 31
    assert sorted(out) == [10, 10, 11]
    # Zero entries are never driven negative.
    out = round_batches([0.0, 2.0, 30.0], 31)
    assert sum(out) == 31
    assert min(out) >= 0
    # Overshoot of >= 1 sample per node is a caller bug, not residue: raise.
    with pytest.raises(ValueError):
        round_batches([10.2, 10.2], 10)
    with pytest.raises(ValueError):
        round_batches([1.0, 1.0], -2)


def test_goodput_curve_matches_scalar_goodput():
    rng = np.random.default_rng(11)
    model = random_model(rng, 8)
    cands = [32.0, 64.0, 128.0, 512.0, 2048.0]
    curve = goodput_curve(model, cands, b_noise=300.0, ref_batch=32)
    for j, b in enumerate(cands):
        gp, _ = goodput(model, b, 300.0, 32, solver="waterfill")
        assert curve.goodputs[j] == pytest.approx(gp, rel=1e-6)
    best_b, best_sol, best_gp = curve.best()
    assert best_b == cands[curve.best_index()]
    assert best_gp == pytest.approx(curve.goodputs.max())
    assert sum(best_sol.batches) == pytest.approx(best_b, rel=1e-9)


def test_selector_engines_agree():
    """Batched and scalar sweep engines pick the same candidate and emit the
    same solution for the winner."""
    rng = np.random.default_rng(23)
    for trial in range(10):
        model = random_model(rng, int(rng.integers(2, 24)))
        cands = tuple(int(b) for b in (64, 128, 256, 512, 1024, 2048))
        b_noise = float(rng.uniform(50, 5000))
        sel_b = BatchSizeSelector(candidates=cands, ref_batch=64, engine="batched")
        sel_s = BatchSizeSelector(candidates=cands, ref_batch=64, engine="scalar")
        got_b = sel_b.select(model, b_noise)
        got_s = sel_s.select(model, b_noise)
        assert got_b[0] == got_s[0]
        assert got_b[1].batches == got_s[1].batches
        assert got_b[2] == pytest.approx(got_s[2], rel=1e-9)


def test_controller_plans_identical_across_engines():
    """Acceptance: the controller produces identical epoch plans (same chosen
    B, same integer partitions) with the batched sweep and the scalar one, on
    seeded noisy scenarios."""
    profiles, comm = cluster_B()
    for seed in (0, 1, 2):
        plans = {}
        for engine in ("batched", "scalar"):
            sim = SimulatedCluster(profiles, comm, noise=0.01, seed=seed)
            ctrl = CannikinController(
                sim.n,
                batch_candidates=[128, 256, 512, 1024, 2048, 4096],
                ref_batch=128,
                sweep_engine=engine,
            )
            out = []
            for _ in range(8):
                plan = ctrl.plan_epoch()
                _, ms = sim.run_epoch(list(plan.batches), steps=5)
                ctrl.observe_epoch(ms)
                ctrl.observe_gradients([4.0] * sim.n, 3.0, list(plan.batches))
                out.append((plan.total_batch, plan.batches, plan.lr_scale))
            plans[engine] = out
        assert plans["batched"] == plans["scalar"]

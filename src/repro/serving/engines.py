"""Decode engines behind the serving runtime: simulated and real.

The serving twin of the trainer's :class:`~repro.runtime.backend.
ExecutionBackend` seam: the queue/allocator/metrics layers are identical
whether ticks are *simulated* from per-node cost laws (heterogeneous
clusters on one CPU — the bench's 2-speed-class gate) or *measured* from
real JAX decode steps over the model zoo (the reduced-olmo req/s floor).

An engine implements three calls, all per node:

* ``prefill(node, admitted)`` — build each admitted request's KV cache over
  its context (prompt + any tokens generated before a requeue) and emit its
  next token; returns the seconds spent.
* ``decode(node, actives)`` — one continuous-batching tick: every active
  request gains one token; returns the tick seconds (what the allocator's
  ``(batch, tick_time)`` refit telemetry observes).
* ``release(ar)`` — the request left the node (completed / requeued);
  drop its cache.

:class:`SimServingEngine` is deterministic (token values are a pure
function of (rid, step); times come from ground-truth coefficient laws), so
same-seed serving runs are bit-identical end to end.
:class:`RealServingEngine` runs batch-1 slot caches through the zoo's
``init_cache``/``decode_step`` plus the fused full-sequence ``prefill``
where the family supports it (:func:`prefill_cache` falls back to the
stepped loop otherwise).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Protocol, Tuple

import numpy as np

from repro.serving.queue import ActiveRequest

__all__ = [
    "ServingEngine",
    "SimServingEngine",
    "RealServingEngine",
    "prefill_cache",
]


class ServingEngine(Protocol):
    vocab: int

    def prefill(self, node: int, admitted: List[ActiveRequest]) -> float: ...

    def decode(self, node: int, actives: List[ActiveRequest]) -> float: ...

    def release(self, ar: ActiveRequest) -> None: ...


def _sim_token(rid: int, step: int, vocab: int) -> int:
    """Deterministic stand-in token stream (no model in the simulator)."""
    return (rid * 1000003 + step * 7919) % max(vocab, 1)


class SimServingEngine:
    """Tick times from ground-truth per-node linear cost laws.

    ``coeffs[node] = (alpha, c)``: a decode tick over ``b`` active slots
    takes ``alpha * b + c`` seconds; a prefill over ``P`` total context
    tokens takes ``alpha * P * prefill_factor + c`` (prefill processes the
    whole sequence in one fused pass, hence the < 1 factor).
    ``set_speed(node, factor)`` rescales a node mid-run — the capacity-drift
    vehicle the allocator's refit path is tested against.
    """

    def __init__(
        self,
        coeffs: Dict[int, Tuple[float, float]],
        *,
        vocab: int = 512,
        prefill_factor: float = 0.25,
    ):
        self._coeffs = {
            int(n): (float(a), float(c)) for n, (a, c) in coeffs.items()
        }
        self.vocab = int(vocab)
        self.prefill_factor = float(prefill_factor)

    def coeffs(self, node: int) -> Tuple[float, float]:
        return self._coeffs[node]

    def set_speed(self, node: int, factor: float) -> None:
        """Make ``node`` ``factor``x faster (slope and intercept divided)."""
        if factor <= 0:
            raise ValueError("speed factor must be positive")
        a, c = self._coeffs[node]
        self._coeffs[node] = (a / factor, c / factor)

    def prefill(self, node: int, admitted: List[ActiveRequest]) -> float:
        if not admitted:
            return 0.0
        a, c = self._coeffs[node]
        ctx = sum(ar.context_len for ar in admitted)
        for ar in admitted:
            ar.tokens.append(_sim_token(ar.rid, len(ar.tokens), self.vocab))
        return a * ctx * self.prefill_factor + c

    def decode(self, node: int, actives: List[ActiveRequest]) -> float:
        if not actives:
            return 0.0
        a, c = self._coeffs[node]
        for ar in actives:
            ar.tokens.append(_sim_token(ar.rid, len(ar.tokens), self.vocab))
        return a * len(actives) + c

    def release(self, ar: ActiveRequest) -> None:  # no per-request state
        return None


# ---------------------------------------------------------------------------
# Real engine: the model zoo under the serving path
# ---------------------------------------------------------------------------


def prefill_cache(api, params, cache, tokens, *, decode_fn=None):
    """Prefill ``cache`` over ``tokens`` (B, S): fused where the family
    supports it, stepped single-token loop otherwise.

    Returns ``(logits_last, cache)`` where ``logits_last`` is (B, 1, V) for
    the final prompt position — argmax it for the first generated token.
    ``decode_fn`` optionally substitutes a jitted ``api.decode_step``.
    """
    if api.supports_prefill():
        logits, cache = api.prefill(params, cache, tokens)
        return logits[:, -1:], cache
    import jax.numpy as jnp

    decode = decode_fn if decode_fn is not None else api.decode_step
    logits = None
    for pos in range(tokens.shape[1]):
        logits, cache = decode(
            params, cache, tokens[:, pos : pos + 1], jnp.int32(pos)
        )
    return logits[:, -1:], cache


class RealServingEngine:
    """Continuous batching over real batch-1 slot caches.

    Each active request owns a ``(batch=1, max_len)`` KV cache; a decode
    tick steps every active slot once through the jitted ``decode_step``
    and the tick time is the *measured* wall time — real telemetry into the
    same allocator refit path the simulator feeds.  Prefill goes through
    :func:`prefill_cache` (fused full-sequence where supported), compiled
    once per distinct context length, so real workloads should quantize
    prompt lengths to a few buckets.

    "Nodes" share this host — heterogeneous speed classes are the
    simulator's job; the real engine is the end-to-end correctness +
    absolute-throughput lane.
    """

    def __init__(self, api, params, *, max_len: int = 256,
                 prompts: Optional[Dict[int, np.ndarray]] = None):
        import jax

        self.api = api
        self.params = params
        self.vocab = int(api.cfg.vocab)
        self.max_len = int(max_len)
        self._prompts = prompts or {}
        self._decode = jax.jit(api.decode_step)
        self._prefill = jax.jit(api.prefill) if api.supports_prefill() else None
        self._slots: Dict[int, dict] = {}  # rid -> {"cache", "pos", "last"}

    def _context_tokens(self, ar: ActiveRequest) -> np.ndarray:
        prompt = self._prompts.get(ar.rid)
        if prompt is None:
            prompt = ar.request.prompt_tokens(self.vocab)
        return np.concatenate(
            [np.asarray(prompt, np.int32), np.asarray(ar.tokens, np.int32)]
        )

    def prefill(self, node: int, admitted: List[ActiveRequest]) -> float:
        import jax
        import jax.numpy as jnp

        t0 = time.perf_counter()
        for ar in admitted:
            ctx = self._context_tokens(ar)
            total = ar.request.prompt_len + ar.request.gen_len
            if total > self.max_len:
                raise ValueError(
                    f"request {ar.rid} needs {total} positions > max_len {self.max_len}"
                )
            cache = self.api.init_cache(1, self.max_len)
            toks = jnp.asarray(ctx[None, :], jnp.int32)
            if self._prefill is not None:
                logits, cache = self._prefill(self.params, cache, toks)
                logits = logits[:, -1:]
            else:
                logits, cache = prefill_cache(
                    self.api, self.params, cache, toks, decode_fn=self._decode
                )
            tok = int(jax.device_get(jnp.argmax(logits[0, -1])))
            ar.tokens.append(tok)
            self._slots[ar.rid] = {"cache": cache, "pos": len(ctx), "last": tok}
        return time.perf_counter() - t0

    def decode(self, node: int, actives: List[ActiveRequest]) -> float:
        import jax
        import jax.numpy as jnp

        t0 = time.perf_counter()
        for ar in actives:
            slot = self._slots[ar.rid]
            logits, cache = self._decode(
                self.params,
                slot["cache"],
                jnp.asarray([[slot["last"]]], jnp.int32),
                jnp.int32(slot["pos"]),
            )
            tok = int(jax.device_get(jnp.argmax(logits[0, -1])))
            ar.tokens.append(tok)
            self._slots[ar.rid] = {"cache": cache, "pos": slot["pos"] + 1, "last": tok}
        return time.perf_counter() - t0

    def release(self, ar: ActiveRequest) -> None:
        self._slots.pop(ar.rid, None)

"""§5.3 reproduction: OptPerf prediction error with and without
inverse-variance weighting of gamma, under heteroscedastic measurement noise
(Fig. 6's per-GPU gamma noise)."""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row, save_json
from repro.core.optperf import solve_optperf_algorithm1
from repro.core.perf_model import (
    ClusterPerfModel,
    CommModel,
    GammaAggregator,
    NodeObservation,
    OnlineNodeFitter,
)
from repro.core.simulator import SimulatedCluster, cluster_A


def learn(sim, epochs=6, steps=6, use_ivw=True, seed=0):
    rng = np.random.default_rng(seed)
    fitters = {i: OnlineNodeFitter() for i in range(sim.n)}
    for _ in range(epochs):
        batches = [int(rng.integers(8, 96)) for _ in range(sim.n)]
        _, ms = sim.run_epoch(batches, steps)
        for i in range(sim.n):
            obs = [m.observations[i] for m in ms]
            fitters[i].add(
                NodeObservation(
                    batch_size=batches[i],
                    a_time=float(np.mean([o.a_time for o in obs])),
                    backprop_time=float(np.mean([o.backprop_time for o in obs])),
                    gamma=float(np.mean([o.gamma for o in obs])),
                    comm_time=float(np.min([o.comm_time for o in obs])),
                )
            )
    agg = GammaAggregator(fitters)
    if use_ivw:
        gamma = agg.gamma()
    else:
        gamma = float(np.mean([f.gamma_stats()[0] for f in fitters.values()]))
    return ClusterPerfModel(
        nodes=tuple(fitters[i].fit() for i in range(sim.n)),
        comm=CommModel(t_o=sim.comm.t_o, t_u=sim.comm.t_u, gamma=gamma),
    )


def run() -> List[Row]:
    profiles, comm = cluster_A()
    errors = {"ivw": [], "plain": []}
    for seed in range(8):
        # Strongly heteroscedastic gamma noise across nodes (Fig. 6).
        sim = SimulatedCluster(
            profiles, comm, noise=0.03,
            per_node_gamma_noise=[0.02, 0.25, 0.45], seed=seed,
        )
        truth = sim.true_model()
        for use_ivw in (True, False):
            model = learn(sim, use_ivw=use_ivw, seed=seed)
            errs = []
            for B in (64, 128, 256, 512):
                pred = solve_optperf_algorithm1(model, B)
                actual = truth.cluster_time(list(pred.batches))
                errs.append(abs(pred.opt_perf - actual) / actual)
            errors["ivw" if use_ivw else "plain"].append(max(errs))
    max_ivw = float(np.max(errors["ivw"]))
    max_plain = float(np.max(errors["plain"]))
    save_json("prediction_error", {"max_error_ivw": max_ivw,
                                   "max_error_plain": max_plain,
                                   "per_seed": errors})
    return [
        Row("prediction/max_error_with_ivw", 0.0, f"{max_ivw:.1%}"),
        Row("prediction/max_error_without_ivw", 0.0, f"{max_plain:.1%}"),
    ]

"""Uniform model API over the six architecture families.

Every family module exposes slightly different signatures (whisper takes
(audio, tokens); MoE forwards return router stats).  `ModelApi` normalizes:

  api.init(rng)                          -> params
  api.loss(params, batch)                -> (scalar loss, aux dict)
  api.logits(params, batch)              -> logits
  api.init_cache(batch_size, seq_len)    -> cache pytree
  api.decode_step(params, cache, tok, pos) -> (logits, cache)
  api.schema() / api.specs(rules)        -> param schema / PartitionSpecs
  api.train_batch_specs(batch, seq)      -> {name: ShapeDtypeStruct}
  api.batch_sharding(rules, batch_keys)  -> {name: PartitionSpec}

`batch` is a dict with integer token arrays plus an optional per-sample
weight vector "weights" (B,) — the Eq. (9) heterogeneous aggregation hook:
a weighted-SUM cross-entropy normalized by total weight reproduces
g = sum_i r_i g_i exactly (see core/aggregation.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common, deepseek, dense, hymba, moe, rwkv6, whisper
from repro.sharding.rules import MeshRules

__all__ = ["ModelApi", "build_api"]

MOE_LB_WEIGHT = 0.01
MOE_Z_WEIGHT = 1e-3


def _token_loss(logits, labels, weights, denom=None):
    """Per-token CE.  ``denom`` overrides the normalizer — used by gradient
    accumulation so microbatch gradients sum to the exact global-batch
    gradient even with non-uniform per-sample weights (Eq. 9)."""
    if weights is not None:
        w = jnp.broadcast_to(weights[:, None], labels.shape).astype(jnp.float32)
    else:
        w = None
    loss_sum, w_sum = common.weighted_cross_entropy(logits, labels, w)
    if denom is None:
        denom = (
            w_sum * labels.shape[-1] if weights is not None else jnp.float32(labels.size)
        )
        denom = jnp.maximum(w_sum if weights is not None else denom, 1e-9)
    return loss_sum / denom


@dataclasses.dataclass
class ModelApi:
    arch_id: str
    cfg: Any
    family: str
    _module: Any
    is_encoder_decoder: bool = False
    has_moe_stats: bool = False

    # -- params ---------------------------------------------------------
    def schema(self):
        return self._module.schema(self.cfg)

    def init(self, rng: jax.Array):
        return self._module.init(rng, self.cfg)

    def specs(self, rules: MeshRules):
        return common.specs_from_schema(self.schema(), rules)

    def param_count(self) -> int:
        return common.param_count(self.schema())

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top-k routed experts
        only) — the N in MODEL_FLOPS = 6*N*D (§Roofline)."""
        total = self.param_count()
        cfg = self.cfg
        if isinstance(cfg, moe.MixtralConfig):
            expert = 3 * cfg.d_model * cfg.d_ff  # swiglu expert
            inactive = cfg.n_layers * (cfg.n_experts - cfg.top_k) * expert
            return total - inactive
        if isinstance(cfg, deepseek.DeepSeekConfig):
            expert = 3 * cfg.d_model * cfg.d_ff_expert
            inactive = (cfg.n_layers - 1) * (cfg.n_experts - cfg.top_k) * expert
            return total - inactive
        return total

    # -- forward/loss ---------------------------------------------------
    def logits(self, params, batch: Dict[str, jax.Array]):
        if self.is_encoder_decoder:
            out = self._module.forward(
                params, self.cfg, batch["audio_embed"], batch["tokens"]
            )
        else:
            out = self._module.forward(params, self.cfg, batch["tokens"])
        if self.has_moe_stats:
            return out[0]
        return out

    def loss(
        self, params, batch: Dict[str, jax.Array], *, denom=None
    ) -> Tuple[jax.Array, Dict]:
        weights = batch.get("weights")
        aux: Dict[str, jax.Array] = {}
        if self.is_encoder_decoder:
            logits = self._module.forward(
                params, self.cfg, batch["audio_embed"], batch["tokens"]
            )
        elif self.has_moe_stats:
            logits, stats = self._module.forward(params, self.cfg, batch["tokens"])
            aux.update(stats)
        else:
            logits = self._module.forward(params, self.cfg, batch["tokens"])
        loss = _token_loss(logits, batch["labels"], weights, denom)
        if self.has_moe_stats:
            loss = loss + MOE_LB_WEIGHT * aux["lb_loss"] + MOE_Z_WEIGHT * aux["z_loss"]
        aux["ce_loss"] = loss
        return loss, aux

    # -- serving --------------------------------------------------------
    def init_cache(self, batch: int, seq_len: int, dtype=None):
        # None defers to each family's default: the config's compute dtype,
        # which is what decode_step writes into the cache.
        return self._module.init_cache(self.cfg, batch, seq_len, dtype)

    def decode_step(self, params, cache, tokens, pos):
        return self._module.decode_step(params, self.cfg, cache, tokens, pos)

    def supports_prefill(self) -> bool:
        """True if the family has a fused full-sequence prefill (one forward
        pass fills the KV cache); otherwise callers step the decode loop."""
        return hasattr(self._module, "prefill")

    def prefill(self, params, cache, tokens):
        """Fused prompt ingestion: (logits (B, S, V), cache at pos=S)."""
        if not self.supports_prefill():
            raise NotImplementedError(
                f"{self.arch_id} ({self.family}) has no fused prefill; "
                "use the stepped decode_step loop"
            )
        return self._module.prefill(params, self.cfg, cache, tokens)

    def supports_long_context(self) -> bool:
        """True if decode over 500k positions is sub-quadratic / bounded-cache."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.arch_id.startswith("whisper"):
            return False
        cfg = self.cfg
        if getattr(cfg, "decode_window", None) is not None:
            return True
        if isinstance(cfg, deepseek.DeepSeekConfig):
            return True  # MLA latent cache: 576 floats/token
        return False

    def cache_logical_axes(self) -> Dict[str, Tuple]:
        """Logical axes per cache leaf name (leading dim = stacked layers)."""
        if self.arch_id.startswith("whisper"):
            kv = (None, "batch", "cache_seq", "heads", None)
            return {"k": kv, "v": kv, "cross_k": kv, "cross_v": kv, "pos": ()}
        if self.family == "ssm":  # rwkv6
            return {
                "wkv": (None, "batch", "heads", None, None),
                "time_shift": (None, "batch", None),
                "chan_shift": (None, "batch", None),
                "pos": (),
            }
        if self.family == "hybrid":  # hymba
            kv = (None, "batch", "cache_seq", "kv_heads", None)
            return {
                "k": kv,
                "v": kv,
                "ssm": (None, "batch", "ssm_inner", None),
                "conv": (None, "batch", None, "ssm_inner"),
                "pos": (),
            }
        if isinstance(self.cfg, deepseek.DeepSeekConfig):
            return {
                "c": (None, "batch", "cache_seq", None),
                "kr": (None, "batch", "cache_seq", None),
                "pos": (),
            }
        kv = (None, "batch", "cache_seq", "kv_heads", None)
        return {"k": kv, "v": kv, "pos": ()}

    def cache_specs(self, rules: MeshRules, batch: int, seq_len: int):
        """PartitionSpec pytree for the decode cache (divisibility-checked)."""
        shapes = jax.eval_shape(lambda: self.init_cache(batch, seq_len))
        axes = self.cache_logical_axes()
        return {
            name: rules.spec(axes[name], sds.shape, path=f"cache/{name}")
            for name, sds in shapes.items()
        }

    # -- dry-run input specs --------------------------------------------
    def train_batch_specs(self, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
        if self.is_encoder_decoder:
            st = max(seq // 4, 8)
            return {
                "audio_embed": jax.ShapeDtypeStruct(
                    (batch, seq, self.cfg.d_model), jnp.bfloat16
                ),
                "tokens": jax.ShapeDtypeStruct((batch, st), jnp.int32),
                "labels": jax.ShapeDtypeStruct((batch, st), jnp.int32),
                "weights": jax.ShapeDtypeStruct((batch,), jnp.float32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "weights": jax.ShapeDtypeStruct((batch,), jnp.float32),
        }

    def batch_sharding(self, rules: MeshRules, specs: Dict[str, Any]) -> Dict[str, Any]:
        out = {}
        for name, sds in specs.items():
            extra = len(sds.shape) - 1
            out[name] = rules.batch_spec(extra_dims=extra)
        return out


def build_api(arch_id: str, cfg: Any) -> ModelApi:
    if isinstance(cfg, dense.DenseConfig):
        return ModelApi(arch_id, cfg, cfg.family, dense)
    if isinstance(cfg, moe.MixtralConfig):
        return ModelApi(arch_id, cfg, cfg.family, moe, has_moe_stats=True)
    if isinstance(cfg, deepseek.DeepSeekConfig):
        return ModelApi(arch_id, cfg, cfg.family, deepseek, has_moe_stats=True)
    if isinstance(cfg, rwkv6.RWKV6Config):
        return ModelApi(arch_id, cfg, cfg.family, rwkv6)
    if isinstance(cfg, hymba.HymbaConfig):
        return ModelApi(arch_id, cfg, cfg.family, hymba)
    if isinstance(cfg, whisper.WhisperConfig):
        return ModelApi(arch_id, cfg, cfg.family, whisper, is_encoder_decoder=True)
    raise TypeError(f"unknown config type {type(cfg)}")

"""OptPerf solver tests: Algorithm 1 vs the water-fill oracle, optimality
properties, special cases (App. A), and integer rounding."""
import numpy as np
import pytest

from _hypothesis_compat import hypothesis, st

from repro.core.optperf import (
    round_batches,
    solve_optperf_algorithm1,
    solve_optperf_waterfill,
    solve_optperf_waterfill_subset,
)
from repro.core.perf_model import ClusterPerfModel, CommModel, NodePerfModel


def make_model(qs, ss, ks, ms, t_o, t_u, gamma):
    nodes = tuple(
        NodePerfModel(q=q, s=s, k=k, m=m) for q, s, k, m in zip(qs, ss, ks, ms)
    )
    return ClusterPerfModel(nodes=nodes, comm=CommModel(t_o=t_o, t_u=t_u, gamma=gamma))


coeff = st.floats(1e-4, 8e-3)
intercept = st.floats(0.0, 0.02)


@st.composite
def cluster_strategy(draw):
    n = draw(st.integers(2, 8))
    qs = [draw(coeff) for _ in range(n)]
    ks = [draw(coeff) for _ in range(n)]
    ss = [draw(intercept) for _ in range(n)]
    ms = [draw(intercept) for _ in range(n)]
    t_o = draw(st.floats(0.0, 0.08))
    t_u = draw(st.floats(0.0, 0.02))
    gamma = draw(st.floats(0.02, 0.6))
    return make_model(qs, ss, ks, ms, t_o, t_u, gamma)


@hypothesis.given(cluster_strategy(), st.floats(16, 4096))
@hypothesis.settings(max_examples=150, deadline=None)
def test_algorithm1_matches_waterfill_oracle(model, total_batch):
    """Paper Algorithm 1 and the exact bisection oracle agree."""
    s1 = solve_optperf_algorithm1(model, total_batch)
    s2 = solve_optperf_waterfill(model, total_batch)
    assert s1.opt_perf == pytest.approx(s2.opt_perf, rel=1e-5, abs=1e-9)
    assert sum(s1.batches) == pytest.approx(total_batch, rel=1e-6)


@hypothesis.given(cluster_strategy(), st.floats(32, 2048), st.integers(0, 100))
@hypothesis.settings(max_examples=100, deadline=None)
def test_perturbation_cannot_improve(model, total_batch, seed):
    """Moving batch mass between nodes never beats the OptPerf solution."""
    sol = solve_optperf_algorithm1(model, total_batch)
    rng = np.random.default_rng(seed)
    b = np.asarray(sol.batches)
    positive = np.where(b > 1e-6)[0]
    if len(positive) < 2:
        return
    i, j = rng.choice(positive, 2, replace=False)
    delta = min(b[i], 0.25 * total_batch) * rng.uniform(0.05, 1.0)
    b2 = b.copy()
    b2[i] -= delta
    b2[j] += delta
    assert model.cluster_time(list(b2)) >= sol.opt_perf * (1 - 1e-9)


def test_all_compute_bottleneck_equalizes_t_compute():
    """App A.1: when comm is negligible, OptPerf equalizes compute times."""
    model = make_model(
        qs=[1e-3, 2e-3, 4e-3], ss=[0.01, 0.01, 0.02],
        ks=[2e-3, 3e-3, 6e-3], ms=[0.005, 0.01, 0.01],
        t_o=1e-6, t_u=1e-6, gamma=0.1,
    )
    sol = solve_optperf_algorithm1(model, 512)
    assert set(sol.bottleneck) == {"compute"}
    times = [model.nodes[i].t_compute(b) for i, b in enumerate(sol.batches)]
    assert max(times) - min(times) < 1e-8


def test_all_comm_bottleneck_equalizes_syncstart():
    """App A.2: with huge T_o every node is comm-bottleneck and syncStarts
    equalize."""
    model = make_model(
        qs=[1e-3, 2e-3], ss=[0.001, 0.002],
        ks=[1e-3, 2e-3], ms=[0.001, 0.002],
        t_o=10.0, t_u=0.01, gamma=0.1,
    )
    sol = solve_optperf_algorithm1(model, 64)
    assert set(sol.bottleneck) == {"comm"}
    gamma = model.comm.gamma
    starts = [model.nodes[i].sync_start(b, gamma) for i, b in enumerate(sol.batches)]
    assert max(starts) - min(starts) < 1e-8


def test_mixed_bottleneck_consistency():
    """A cluster engineered to straddle the boundary: the returned partition
    must be self-consistent with the overlap-state criterion."""
    model = make_model(
        qs=[5e-4, 5e-3], ss=[0.001, 0.001],
        ks=[5e-4, 8e-3], ms=[0.001, 0.02],
        t_o=0.03, t_u=0.005, gamma=0.2,
    )
    sol = solve_optperf_algorithm1(model, 256)
    for i, (b, kind) in enumerate(zip(sol.batches, sol.bottleneck)):
        assert model.is_compute_bottleneck(i, b) == (kind == "compute")


def test_faster_node_gets_larger_batch():
    model = make_model(
        qs=[1e-3, 3e-3], ss=[0.01, 0.01], ks=[1.5e-3, 4.5e-3], ms=[0.008, 0.008],
        t_o=0.02, t_u=0.005, gamma=0.15,
    )
    sol = solve_optperf_algorithm1(model, 300)
    assert sol.batches[0] > sol.batches[1]


def test_boundary_hint_matches_unhinted():
    model = make_model(
        qs=[5e-4, 1e-3, 5e-3], ss=[0.001, 0.002, 0.001],
        ks=[5e-4, 2e-3, 8e-3], ms=[0.001, 0.01, 0.02],
        t_o=0.03, t_u=0.005, gamma=0.2,
    )
    base = solve_optperf_algorithm1(model, 200)
    for hint in range(4):
        hinted = solve_optperf_algorithm1(model, 200, boundary_hint=hint)
        assert hinted.opt_perf == pytest.approx(base.opt_perf, rel=1e-9)


@hypothesis.given(
    st.lists(st.floats(0.0, 200.0), min_size=2, max_size=10),
)
@hypothesis.settings(max_examples=100, deadline=None)
def test_round_batches_sums_exactly(batches):
    total = int(round(sum(batches)))
    if total < sum(int(np.floor(b)) for b in batches) or total <= 0:
        return
    rounded = round_batches(batches, total)
    assert sum(rounded) == total
    assert all(abs(r - b) <= 1.0 + 1e-9 for r, b in zip(rounded, batches))


def test_waterfill_handles_clamping():
    """A hopeless straggler gets zero batch (Algorithm 1's linear solve would
    go negative; the oracle clamps)."""
    model = make_model(
        qs=[1e-4, 1.0], ss=[0.0, 10.0], ks=[1e-4, 1.0], ms=[0.0, 10.0],
        t_o=0.001, t_u=0.001, gamma=0.1,
    )
    sol = solve_optperf_waterfill(model, 64)
    assert sol.batches[1] == 0.0
    assert sol.batches[0] == pytest.approx(64.0)


def test_waterfill_subset_bit_identical_to_subset_model():
    """The subset gather path (the scheduler's chosen-set re-solve) must be
    bit-identical to building the subset ClusterPerfModel — coefficients
    are per-node, so gathered rows are the exact same floats and the
    bisection follows the exact same trajectory."""
    rng = np.random.default_rng(17)
    n = 9
    model = make_model(
        qs=rng.uniform(1e-4, 5e-3, n), ss=rng.uniform(0, 0.02, n),
        ks=rng.uniform(1e-4, 8e-3, n), ms=rng.uniform(0, 0.02, n),
        t_o=0.03, t_u=0.006, gamma=0.2,
    )
    for trial in range(10):
        size = int(rng.integers(1, n + 1))
        ids = tuple(int(i) for i in rng.choice(n, size=size, replace=False))
        total = float(rng.uniform(16, 2048))
        sub = solve_optperf_waterfill_subset(model, ids, total)
        ref_model = ClusterPerfModel(
            nodes=tuple(model.nodes[i] for i in ids), comm=model.comm
        )
        ref = solve_optperf_waterfill(ref_model, total)
        assert sub.opt_perf == ref.opt_perf          # bitwise, not approx
        assert sub.batches == ref.batches
        assert sub.bottleneck == ref.bottleneck


def test_waterfill_subset_validates_only_the_subset():
    """A bad node outside the subset must not reject a valid sub-cluster
    (and a bad node inside it must)."""
    good = dict(q=1e-3, s=0.0, k=1e-3, m=0.0)
    model = ClusterPerfModel(
        nodes=(
            NodePerfModel(**good),
            NodePerfModel(q=1e-3, s=0.0, k=-1.0, m=0.0),  # ill-posed
        ),
        comm=CommModel(t_o=0.01, t_u=0.001, gamma=0.1),
    )
    sol = solve_optperf_waterfill_subset(model, (0,), 64)
    assert sol.opt_perf > 0
    with pytest.raises(ValueError):
        solve_optperf_waterfill_subset(model, (0, 1), 64)
    with pytest.raises(ValueError):
        solve_optperf_waterfill_subset(model, (), 64)


def test_algorithm1_batch_bit_equal_to_scalar_sweep():
    """The vectorized closed-form boundary checks reproduce the scalar
    Algorithm 1 sweep bit-for-bit: over seeded random clusters and candidate
    vectors, every batched row equals the scalar solution (with §4.5 hint
    chaining) field-for-field -- the scalar path is the exactness oracle."""
    from repro.core.optperf import solve_optperf_algorithm1_batch

    methods = set()
    for seed in range(40):
        rng = np.random.default_rng(31_000 + seed)
        n = int(rng.integers(2, 12))
        model = make_model(
            qs=rng.uniform(1e-4, 8e-3, n),
            ss=rng.uniform(0.0, 0.02, n),
            ks=rng.uniform(1e-4, 8e-3, n),
            ms=rng.uniform(0.0, 0.02, n),
            t_o=float(10.0 ** rng.uniform(-4, -1)),
            t_u=float(rng.uniform(0.0, 0.02)),
            gamma=float(rng.uniform(0.02, 0.6)),
        )
        cands = np.unique(np.round(rng.uniform(8, 8192, size=6)))
        batch = solve_optperf_algorithm1_batch(model, cands)
        hint = None
        for j, b in enumerate(cands):
            ref = solve_optperf_algorithm1(model, float(b), boundary_hint=hint)
            hint = sum(1 for s in ref.bottleneck if s == "compute")
            got = batch[j]
            assert got.total_batch == ref.total_batch
            assert got.opt_perf == ref.opt_perf          # bit-exact
            assert got.batches == ref.batches            # bit-exact tuples
            assert got.bottleneck == ref.bottleneck
            assert got.method == ref.method
            methods.add(got.method)
    # The seeded sweep must actually exercise the vectorized closed forms.
    assert any(m.startswith("algorithm1/check") for m in methods)

"""Sharding: logical-axis rules and mesh helpers."""
from repro.sharding.rules import Fallback, MeshRules

__all__ = ["MeshRules", "Fallback"]

"""HeteroTrainer: end-to-end Cannikin training over a (simulated) hetero cluster.

Runs *real* JAX training of a model on this host while a `SimulatedCluster`
supplies the wall-clock the heterogeneous cluster would have taken — the
separation the paper itself makes between statistical behaviour (identical to
homogeneous training thanks to Eq. 9) and system behaviour (per-node timing).

Since the ExecutionBackend refactor this class is a thin compatibility shell:
the gradient engine lives in :class:`repro.runtime.backend.RealBackend`
(vmapped per-node backward, Eq. 9 aggregation, Theorem-4.1 GNS tracking,
simulated clock, preemption snapshot/restore) and the plan → execute →
observe policy loop in :class:`repro.runtime.backend.EpochLoop` — the same
loop ``JobHandle.advance`` drives inside the cluster runtime.  `HeteroTrainer`
keeps the historical constructor and the :class:`EpochResult` history format
for existing callers; new code should use the backend/loop API directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

from repro.core.simulator import SimulatedCluster
from repro.data.pipeline import SyntheticLM
from repro.models.registry import ModelApi
from repro.optim.optimizers import Optimizer
from repro.runtime.backend import EpochLoop, EpochRecord, RealBackend

__all__ = ["HeteroTrainer", "EpochResult"]


@dataclasses.dataclass
class EpochResult:
    """Per-epoch summary in the historical (pre-:class:`EpochRecord`)
    layout, kept for JSON dumps and existing callers."""

    epoch: int
    total_batch: int
    batches: Tuple[int, ...]
    sim_seconds: float          # simulated cluster wall-clock for the epoch
    mean_loss: float
    predicted_batch_time: Optional[float]
    measured_batch_time: float
    b_noise: float
    lr_scale: float
    phase: str

    @classmethod
    def from_record(cls, record: EpochRecord) -> "EpochResult":
        return cls(
            epoch=record.epoch,
            total_batch=record.total_batch,
            batches=record.batches,
            sim_seconds=record.epoch_seconds,
            mean_loss=record.mean_loss,
            predicted_batch_time=record.predicted_batch_time,
            measured_batch_time=record.measured_batch_time,
            b_noise=record.b_noise,
            lr_scale=record.lr_scale,
            phase=record.phase,
        )


class HeteroTrainer:
    def __init__(
        self,
        api: ModelApi,
        optimizer: Optimizer,
        cluster: SimulatedCluster,
        policy: Any,                       # CannikinController or baseline
        data: SyntheticLM,
        *,
        steps_per_epoch: int = 8,
        seed: int = 0,
    ) -> None:
        self.api = api
        self.optimizer = optimizer
        self.cluster = cluster
        self.policy = policy
        self.data = data
        self.steps_per_epoch = steps_per_epoch
        self.backend = RealBackend(
            api,
            optimizer,
            data,
            cluster=cluster,
            seed=seed,
            gns_decay=getattr(policy, "gns_decay", 0.9),
        )
        self.loop = EpochLoop(
            policy, self.backend, steps_per_epoch=steps_per_epoch
        )
        self.history: List[EpochResult] = []

    # -- state passthrough (historical surface) --------------------------

    @property
    def params(self):
        return self.backend.params

    @params.setter
    def params(self, value) -> None:
        self.backend.params = value

    @property
    def opt_state(self):
        return self.backend.opt_state

    @opt_state.setter
    def opt_state(self, value) -> None:
        self.backend.opt_state = value

    @property
    def sim_time(self) -> float:
        return self.backend.sim_time

    # ------------------------------------------------------------------

    def run_epoch(self) -> EpochResult:
        result = EpochResult.from_record(self.loop.run_epoch())
        self.history.append(result)
        return result

    def policy_total_batch(self) -> int:
        """Baselines run fixed total batch (the policy object's ref batch if
        present, else the data default)."""
        return getattr(self.policy, "total_batch", None) or getattr(
            self, "_fixed_total", 64
        )

    def set_fixed_total(self, total: int) -> None:
        self._fixed_total = total
        self.loop.fixed_total = total

    def run(self, epochs: int, *, target_loss: Optional[float] = None) -> List[EpochResult]:
        for _ in range(epochs):
            res = self.run_epoch()
            if target_loss is not None and res.mean_loss <= target_loss:
                break
        return self.history

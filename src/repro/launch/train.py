"""Training driver CLI.

Three modes, each supporting ``--backend {real,sim}`` where it applies:

* ``hetero`` (default) — the paper's end-to-end scenario driven through
  the shared ``EpochLoop``: with ``--backend real`` (default), real JAX
  training of a reduced-config model on this host with per-node timing
  supplied by the calibrated heterogeneous-cluster simulator; with
  ``--backend sim``, the identical loop over the timing simulator alone
  (no gradients — losses are NaN, useful for fast policy/timing studies).
  The chosen policy (cannikin / even / lb-bsp / adaptdl) controls the
  batch partition and, for the adaptive policies, the total batch size.

* ``spmd`` — single-process pjit training of a reduced config on the local
  device(s): the quickstart path (examples/quickstart.py wraps it).

* ``trace`` — multi-job cluster churn through the
  ``repro.runtime.ClusterRuntime`` front door: a seeded synthetic trace
  (arrivals, a departure, a node failure) replayed with training epochs
  between events.  ``--backend sim`` (default) compares all three
  allocation policies; ``--backend real`` runs the cannikin policy with
  every job training real gradients (totals clamped to ``--ref-batch``),
  checkpointing to ``--checkpoint-dir`` on preemption.  ``--arrival
  poisson`` / ``--size-dist lognormal`` sample the arrival process and the
  heavy-tailed job-size skew.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --policy cannikin \
      --cluster B --epochs 12 --steps-per-epoch 8
  PYTHONPATH=src python -m repro.launch.train --mode spmd --arch rwkv6-7b --steps 20
  PYTHONPATH=src python -m repro.launch.train --mode trace --trace-jobs 3 \
      --trace-nodes 12 --epochs-per-event 2 --arrival poisson
  PYTHONPATH=src python -m repro.launch.train --mode trace --backend real \
      --trace-jobs 1 --trace-nodes 3 --epochs-per-event 2 --ref-batch 16
"""
from __future__ import annotations

import argparse
import json
import time
import warnings
from typing import Any, Optional

import numpy as np


def make_policy(name: str, n_nodes: int, *, candidates, ref_batch: int, adaptive: bool):
    """Deprecated shim — use :func:`repro.runtime.make_partition_policy`
    (the shared factory this now delegates to)."""
    warnings.warn(
        "repro.launch.train.make_policy is deprecated; use "
        "repro.runtime.make_partition_policy instead",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.runtime import make_partition_policy

    return make_partition_policy(
        name, n_nodes, candidates=candidates, ref_batch=ref_batch, adaptive=adaptive
    )


def hetero_adaptive(backend: str, fixed_batch: bool, batch_policy: Optional[str]) -> bool:
    """Whether a hetero run's controller adapts its total batch.

    GNS-driven selection (the default law, or any policy with ``"gns"`` in
    its requirements) needs gradient telemetry: under ``--backend sim`` the
    tracker would sit at b_noise=inf and "adaptive" selection would
    escalate the total batch on throughput alone, so those stay forced to
    fixed-batch.  Schedule-driven policies (geodamp/padadamp/adadamp) need
    no gradients and run adaptively on either backend.
    """
    from repro.core.batch_policy import policy_requirements

    if fixed_batch:
        return False
    if backend == "real":
        return True
    return batch_policy is not None and "gns" not in policy_requirements(batch_policy)


def run_hetero(args) -> int:
    from repro.core.simulator import SimulatedCluster, cluster_A, cluster_B, cluster_C
    from repro.runtime import EpochLoop, SimBackend, make_partition_policy

    cluster_fn = {"A": cluster_A, "B": cluster_B, "C": cluster_C}[args.cluster]
    profiles, comm = cluster_fn()
    sim = SimulatedCluster(profiles, comm, noise=args.noise, seed=args.seed)

    candidates = [args.ref_batch * m for m in (1, 2, 4, 8)]
    policy = make_partition_policy(
        args.policy,
        sim.n,
        candidates=candidates,
        ref_batch=args.ref_batch,
        adaptive=hetero_adaptive(args.backend, args.fixed_batch, args.batch_policy),
        batch_policy=args.batch_policy,
    )
    if args.backend == "real":
        from repro.configs import get_api
        from repro.data import SyntheticLM
        from repro.optim import constant_schedule, sgd
        from repro.runtime import RealBackend

        api = get_api(args.arch, reduced=True)
        data = SyntheticLM(vocab=api.cfg.vocab, seq_len=args.seq_len, seed=args.seed)
        backend = RealBackend(
            api, sgd(constant_schedule(args.lr)), data, cluster=sim, seed=args.seed
        )
    else:
        backend = SimBackend(cluster=sim, noise=args.noise)
    loop = EpochLoop(
        policy, backend,
        steps_per_epoch=args.steps_per_epoch, fixed_total=args.ref_batch,
    )
    print(f"# arch={args.arch} policy={args.policy} cluster={args.cluster} "
          f"nodes={sim.n} backend={args.backend}")
    for _ in range(args.epochs):
        r = loop.run_epoch()
        pred = "-" if r.predicted_batch_time is None else f"{r.predicted_batch_time*1e3:.1f}ms"
        print(
            f"epoch {r.epoch:3d} [{r.phase:9s}] B={r.total_batch:4d} "
            f"split={list(r.batches)} loss={r.mean_loss:.4f} "
            f"batch_time={r.measured_batch_time*1e3:.1f}ms pred={pred} "
            f"sim_total={loop.sim_time:.2f}s",
            flush=True,
        )
        if args.target_loss and r.mean_loss <= args.target_loss:
            print(f"# reached target loss {args.target_loss} at sim time "
                  f"{loop.sim_time:.2f}s")
            break
    if args.out:
        # Keep the historical EpochResult record schema (sim_seconds etc.)
        # that existing consumers of --out parse.
        from repro.train.hetero import EpochResult

        with open(args.out, "w") as f:
            json.dump(
                [EpochResult.from_record(r).__dict__ for r in loop.history],
                f, indent=1, default=str,
            )
    return 0


def run_spmd(args) -> int:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_api
    from repro.data import SyntheticLM
    from repro.optim import adamw, constant_schedule
    from repro.train.step import build_train_step

    api = get_api(args.arch, reduced=True)
    opt = adamw(constant_schedule(args.lr))
    step = jax.jit(build_train_step(api, opt, microbatches=args.microbatches))
    params = api.init(jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    data = SyntheticLM(vocab=api.cfg.vocab, seq_len=args.seq_len, seed=args.seed)
    for i in range(args.steps):
        raw = data.batch(i, args.ref_batch)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        t0 = time.perf_counter()
        params, opt_state, metrics = step(params, opt_state, batch)
        loss = float(metrics["loss"])
        print(f"step {i:4d} loss={loss:.4f} "
              f"({(time.perf_counter()-t0)*1e3:.0f}ms)", flush=True)
    return 0


def run_trace(args) -> int:
    from repro.runtime import (
        RealBackendConfig,
        compare_policies,
        format_batch_policy_summary,
        format_summary,
        make_fault_plan,
        synthetic_trace,
    )

    real = args.backend == "real"
    # --batch-policy switches the comparison axis: one allocation policy,
    # one replay per batch-size adaptation law ("all" = whole registry).
    if args.batch_policy is None:
        batch_policies = None
    elif args.batch_policy == "all":
        batch_policies = ()
    else:
        batch_policies = (args.batch_policy,)
    trace, jobs = synthetic_trace(
        args.trace_jobs,
        args.trace_nodes,
        seed=args.seed,
        arrival=args.arrival,
        size_dist=args.size_dist,
        backend=args.backend,
        # Real gradients on this host: clamp the trace's sampled totals to
        # a CPU-sized batch.
        total_batch=args.ref_batch if real else None,
    )
    faults = make_fault_plan(args.faults, args.trace_nodes, seed=args.seed)
    reports = compare_policies(
        trace,
        args.trace_nodes,
        # Real-backend traces train actual models per job per policy; keep
        # the comparison to the cannikin policy unless simulating.
        policies=("cannikin",) if real else ("cannikin", "static", "fair-share"),
        epochs_per_event=args.epochs_per_event,
        steps=args.steps_per_epoch,
        noise=args.noise,
        seed=args.seed,
        real_backend=RealBackendConfig(
            arch=args.arch, seq_len=args.seq_len, lr=args.lr
        ) if real else None,
        checkpoint_dir=args.checkpoint_dir,
        faults=faults,
        invariants=args.invariants,
        batch_policies=batch_policies,
    )
    print(f"# trace: {len(trace)} events, jobs={[j.name for j in jobs]}, "
          f"nodes={args.trace_nodes}")
    if faults is not None:
        for line in faults.describe():
            print(f"# inject: {line}")
        for name, rep in reports.items():
            telemetry = rep.runtime.fault_telemetry()
            if telemetry is None:
                continue
            retention = rep.goodput_retention
            note = f" retention={retention:.3f}" if retention is not None else ""
            if args.invariants:
                inv = telemetry.get("invariants", {})
                note += f" invariant_violations={inv.get('violations', 0)}"
            print(f"# {name}: detected={telemetry['detected']} "
                  f"recoveries={telemetry['recoveries']}{note}")
    if batch_policies is not None:
        print(format_batch_policy_summary(reports))
    else:
        print(format_summary(reports))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({name: rep.summary() for name, rep in reports.items()},
                      f, indent=1)
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", default="hetero", choices=["hetero", "spmd", "trace"])
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--policy", default="cannikin",
                    choices=["cannikin", "even", "ddp", "adaptdl", "lb-bsp"])
    ap.add_argument("--cluster", default="B", choices=["A", "B", "C"])
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--steps-per-epoch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--ref-batch", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--noise", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fixed-batch", action="store_true")
    ap.add_argument("--batch-policy", default=None,
                    help="total-batch adaptation law from the "
                         "repro.core.batch_policy registry (cannikin-gns, "
                         "adadamp, padadamp, geodamp, fixed); in trace mode "
                         "'all' compares every registered policy on one "
                         "trace; default keeps the historical per-backend "
                         "behaviour")
    ap.add_argument("--target-loss", type=float, default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--backend", default=None, choices=["sim", "real"],
                    help="execution backend (default: real for --mode hetero, "
                         "sim for --mode trace)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for preemption checkpoints (trace mode)")
    ap.add_argument("--trace-jobs", type=int, default=3)
    ap.add_argument("--trace-nodes", type=int, default=12)
    ap.add_argument("--faults", default="none",
                    choices=["none", "chaos", "chaos-small", "chaos-real"],
                    help="seeded fault plan injected into trace replays "
                         "(chaos-real adds gradient poison / checkpoint "
                         "corruption / solver stalls for real backends)")
    ap.add_argument("--invariants", action="store_true",
                    help="run the debug-mode runtime invariant checker "
                         "after every reconciled event (trace mode)")
    ap.add_argument("--epochs-per-event", type=int, default=2)
    ap.add_argument("--arrival", default="fixed", choices=["fixed", "poisson"])
    ap.add_argument("--size-dist", default="fixed", choices=["fixed", "lognormal"])
    args = ap.parse_args()
    if args.backend is None:
        args.backend = "real" if args.mode == "hetero" else "sim"
    if args.batch_policy not in (None, "all"):
        from repro.core.batch_policy import BATCH_POLICIES

        if args.batch_policy not in BATCH_POLICIES:
            ap.error(
                f"--batch-policy {args.batch_policy!r} is not registered "
                f"(choose from {sorted(BATCH_POLICIES)} or 'all')"
            )
    if args.mode == "hetero":
        return run_hetero(args)
    if args.mode == "trace":
        return run_trace(args)
    return run_spmd(args)


if __name__ == "__main__":
    raise SystemExit(main())

"""Heterogeneity-aware multi-job scheduler (beyond-paper; the paper's §6
"Adapt to schedulers for heterogeneous clusters" future-work item).

Existing schedulers (Pollux, Optimus) allocate homogeneous slices per job;
Sia is heterogeneity-aware across jobs but keeps each job's allocation
homogeneous.  With Cannikin, a job runs *optimally on any heterogeneous
subset* — its goodput for an arbitrary node set is computable from the
per-node performance models.  That turns scheduling into: partition the
cluster's (heterogeneous) nodes among jobs to maximize aggregate
goodput-fraction.

`allocate` uses greedy marginal-gain assignment (submodular-style):
repeatedly give the next node to the job whose *relative* goodput gains the
most from it.  Each job's goodput for a candidate node set comes from the
OptPerf solver over that subset — the same machinery the controller uses,
so scheduler decisions and runtime behaviour cannot diverge.

This is intentionally a library (allocation policy + simulation harness),
not a daemon: launch integration would wrap `allocate` in a reconcile loop.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.goodput import statistical_efficiency
from repro.core.optperf import solve_optperf_waterfill
from repro.core.perf_model import ClusterPerfModel, CommModel, NodePerfModel

__all__ = ["JobSpec", "Allocation", "allocate", "aggregate_goodput"]


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """A job's statistical state + per-node performance models.

    ``node_models[i]`` is THIS job's fitted model for cluster node i (compute
    coefficients are job-dependent; §4.2).  ``comm`` is the job's fitted
    communication model.
    """

    name: str
    node_models: Tuple[NodePerfModel, ...]   # indexed by cluster node id
    comm: CommModel
    total_batch: int
    b_noise: float
    ref_batch: int
    min_nodes: int = 1

    def goodput(self, node_ids: Sequence[int]) -> float:
        if len(node_ids) < self.min_nodes:
            return 0.0
        model = ClusterPerfModel(
            nodes=tuple(self.node_models[i] for i in node_ids), comm=self.comm
        )
        try:
            sol = solve_optperf_waterfill(model, self.total_batch)
        except (ValueError, RuntimeError):
            return 0.0
        thr = self.total_batch / sol.opt_perf
        return thr * statistical_efficiency(self.b_noise, self.total_batch, self.ref_batch)

    def solo_goodput(self) -> float:
        """Goodput with the whole cluster — the normalizer for fairness."""
        return self.goodput(tuple(range(len(self.node_models))))


@dataclasses.dataclass(frozen=True)
class Allocation:
    assignment: Dict[str, Tuple[int, ...]]   # job -> node ids
    goodputs: Dict[str, float]
    fractions: Dict[str, float]              # goodput / solo goodput

    @property
    def aggregate_fraction(self) -> float:
        return float(sum(self.fractions.values()))


def allocate(jobs: Sequence[JobSpec], n_nodes: int) -> Allocation:
    """Greedy marginal-gain node assignment.

    Seeds every job with its single best node (by marginal goodput), then
    assigns remaining nodes to the job with the largest *normalized*
    marginal gain (gain / solo goodput) — normalization prevents one large
    job from starving small ones (the same normalization Pollux's fair
    goodput objective uses).
    """
    if not jobs:
        return Allocation({}, {}, {})
    remaining = set(range(n_nodes))
    assign: Dict[str, List[int]] = {j.name: [] for j in jobs}
    solo = {j.name: max(j.solo_goodput(), 1e-12) for j in jobs}
    current = {j.name: 0.0 for j in jobs}

    def gain(job: JobSpec, node: int) -> float:
        g = job.goodput(tuple(assign[job.name] + [node]))
        return (g - current[job.name]) / solo[job.name]

    # Seed round: each job (in order of scarcity) takes its best node.
    for job in sorted(jobs, key=lambda j: -j.min_nodes):
        if not remaining:
            break
        best = max(remaining, key=lambda nid: gain(job, nid))
        assign[job.name].append(best)
        current[job.name] = job.goodput(tuple(assign[job.name]))
        remaining.discard(best)

    # Greedy rounds.
    while remaining:
        best_pair: Optional[Tuple[float, str, int]] = None
        for job in jobs:
            for nid in remaining:
                g = gain(job, nid)
                if best_pair is None or g > best_pair[0]:
                    best_pair = (g, job.name, nid)
        g, jname, nid = best_pair
        if g <= 0:
            break  # nobody benefits (comm-bound saturation)
        assign[jname].append(nid)
        job = next(j for j in jobs if j.name == jname)
        current[jname] = job.goodput(tuple(assign[jname]))
        remaining.discard(nid)

    goodputs = {name: current[name] for name in assign}
    fractions = {name: goodputs[name] / solo[name] for name in assign}
    return Allocation(
        assignment={k: tuple(sorted(v)) for k, v in assign.items()},
        goodputs=goodputs,
        fractions=fractions,
    )


def aggregate_goodput(jobs: Sequence[JobSpec], allocation: Allocation) -> float:
    return float(sum(allocation.goodputs.values()))

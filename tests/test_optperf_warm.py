"""Warm-started (incremental re-bracketing) OptPerf: seeded drift scenarios
must converge to the same solutions/plans as cold-start, stale warm starts
must stay correct, membership/regime changes must fall back to cold brackets,
and the stacked multi-row engine must match per-row scalar solves."""
import numpy as np
import pytest

from repro.core.goodput import BatchSizeSelector
from repro.core.optperf import (
    solve_optperf_batch,
    solve_optperf_stacked,
    solve_optperf_waterfill,
)
from repro.core.perf_model import (
    ClusterPerfModel,
    CommModel,
    NodePerfModel,
    StackedClusterModel,
)
from repro.core.simulator import SimulatedCluster, cluster_B, cluster_C, drift_model


def random_model(rng: np.random.Generator, n: int) -> ClusterPerfModel:
    nodes = tuple(
        NodePerfModel(
            q=float(rng.uniform(1e-4, 8e-3)),
            s=float(rng.uniform(0.0, 0.02)),
            k=float(rng.uniform(1e-4, 8e-3)),
            m=float(rng.uniform(0.0, 0.02)),
        )
        for _ in range(n)
    )
    comm = CommModel(
        t_o=float(10.0 ** rng.uniform(-4, -1)),
        t_u=float(rng.uniform(0.0, 0.02)),
        gamma=float(rng.uniform(0.02, 0.6)),
    )
    return ClusterPerfModel(nodes=nodes, comm=comm)


drifted = drift_model  # the shared drift vehicle (same one the bench gates use)


# ---------------------------------------------------------------------------
# solver-level warm-start correctness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 16, 64])
@pytest.mark.parametrize("drift_exp", [-6, -4, -2])
def test_warm_equals_cold_under_drift(n, drift_exp):
    """Across seeded drift magnitudes, warm-started solves match cold ones
    to solver tolerance (same opt_perfs, same partitions)."""
    for seed in range(10):
        rng = np.random.default_rng(1000 * n + seed)
        model = random_model(rng, n)
        cands = np.unique(np.round(rng.uniform(8, 8192, size=6)))
        base = solve_optperf_batch(model, cands)
        new = drifted(model, rel=10.0 ** drift_exp, seed=seed)
        cold = solve_optperf_batch(new, cands)
        warm = solve_optperf_batch(new, cands, warm_start=base.t_stars)
        np.testing.assert_allclose(warm.opt_perfs, cold.opt_perfs, rtol=1e-9)
        np.testing.assert_allclose(warm.batches, cold.batches, atol=1e-5)
        assert np.allclose(warm.batches.sum(axis=1), cands, rtol=1e-9)


def test_warm_uses_far_fewer_evals_under_small_drift():
    rng = np.random.default_rng(7)
    model = random_model(rng, 64)
    cands = np.unique(np.round(np.geomspace(64, 65536, 64)))
    base = solve_optperf_batch(model, cands)
    new = drifted(model, rel=1e-4, seed=3)
    cold = solve_optperf_batch(new, cands)
    warm = solve_optperf_batch(new, cands, warm_start=base.t_stars)
    assert warm.iterations <= 5
    assert cold.iterations >= 5 * warm.iterations


@pytest.mark.parametrize(
    "garbage",
    [
        lambda c: np.zeros(c.shape),
        lambda c: np.full(c.shape, 1e9),
        lambda c: np.full(c.shape, np.nan),
        lambda c: np.full(c.shape, -5.0),
    ],
    ids=["zeros", "huge", "nan", "negative"],
)
def test_garbage_warm_start_still_converges(garbage):
    """The safeguarded Newton keeps a certified bracket: arbitrary warm
    starts give the same answer, only slower."""
    rng = np.random.default_rng(11)
    model = random_model(rng, 12)
    cands = np.asarray([32.0, 256.0, 2048.0])
    cold = solve_optperf_batch(model, cands)
    warm = solve_optperf_batch(model, cands, warm_start=garbage(cands))
    np.testing.assert_allclose(warm.opt_perfs, cold.opt_perfs, rtol=1e-9)


def test_nan_coefficients_rejected_by_validate():
    """The vectorized validate must reject NaN coefficients exactly like the
    per-node loop did (NaN comparisons are False: the check must be written
    in negated-all form) — JobSpec.goodput's graceful 0.0 depends on it."""
    bad = ClusterPerfModel(
        nodes=(
            NodePerfModel(q=float("nan"), s=0.0, k=1e-3, m=0.0),
            NodePerfModel(q=1e-3, s=0.0, k=1e-3, m=0.0),
        ),
        comm=CommModel(t_o=0.01, t_u=0.005, gamma=0.1),
    )
    with pytest.raises(ValueError):
        bad.validate()
    with pytest.raises(ValueError):
        solve_optperf_batch(bad, [64.0])
    bad_k = ClusterPerfModel(
        nodes=(NodePerfModel(q=1e-3, s=0.0, k=float("nan"), m=0.0),),
        comm=CommModel(t_o=0.01, t_u=0.005, gamma=0.1),
    )
    with pytest.raises(ValueError):
        bad_k.validate()


def test_warm_start_shape_mismatch_raises():
    rng = np.random.default_rng(3)
    model = random_model(rng, 4)
    with pytest.raises(ValueError):
        solve_optperf_batch(model, [64.0, 128.0], warm_start=np.zeros(3))


def test_warm_solution_reports_method():
    rng = np.random.default_rng(5)
    model = random_model(rng, 4)
    cold = solve_optperf_batch(model, [64.0])
    warm = solve_optperf_batch(model, [64.0], warm_start=cold.t_stars)
    assert cold.method == "waterfill/batched"
    assert warm.method == "waterfill/batched+warm"
    assert cold.t_stars is not None and warm.t_stars is not None


# ---------------------------------------------------------------------------
# stacked engine
# ---------------------------------------------------------------------------


def test_stacked_matches_per_row_scalar():
    """Each row of a padded heterogeneous-width stack solves exactly like a
    standalone cluster."""
    models, totals = [], []
    for seed in range(25):
        rng = np.random.default_rng(seed)
        models.append(random_model(rng, int(rng.integers(1, 24))))
        totals.append(float(rng.uniform(16, 4096)))
    stack = StackedClusterModel.from_models(models)
    sol = solve_optperf_stacked(stack, totals)
    for r, model in enumerate(models):
        ref = solve_optperf_waterfill(model, totals[r])
        assert sol.opt_perfs[r] == pytest.approx(ref.opt_perf, rel=1e-9)
        row = sol.solution(r)
        assert len(row.batches) == model.n          # padding slots dropped
        assert sum(row.batches) == pytest.approx(totals[r], rel=1e-9)
        # Padding slots never receive batch.
        assert np.all(sol.batches[r, model.n:] == 0.0)


def test_stacked_roundtrip_and_validation():
    rng = np.random.default_rng(9)
    models = [random_model(rng, 3), random_model(rng, 5)]
    stack = StackedClusterModel.from_models(models)
    assert stack.shape == (2, 5)
    # row_model reconstructs the original coefficients.
    rec = stack.row_model(0)
    np.testing.assert_allclose(rec.coeffs.alphas, models[0].coeffs.alphas)
    np.testing.assert_allclose(rec.coeffs.ds, models[0].coeffs.ds)
    with pytest.raises(ValueError):
        StackedClusterModel.from_models([])
    bad = StackedClusterModel(
        alphas=np.ones((1, 2)), cs=np.zeros((1, 2)), betas=np.ones((1, 2)),
        ds=np.zeros((1, 2)), ks=np.ones((1, 2)), ms=np.zeros((1, 2)),
        t_o=np.zeros(1), t_u=np.zeros(1), gamma=np.zeros(1),
        mask=np.zeros((1, 2), dtype=bool),   # no valid slot in the row
    )
    with pytest.raises(ValueError):
        bad.validate()
    with pytest.raises(ValueError):
        solve_optperf_stacked(StackedClusterModel.from_models(models), [64.0])


def test_stacked_warm_start_matches_cold():
    models = [random_model(np.random.default_rng(s), 8) for s in range(10)]
    totals = [256.0] * 10
    stack = StackedClusterModel.from_models(models)
    cold = solve_optperf_stacked(stack, totals)
    warm = solve_optperf_stacked(stack, totals, warm_start=cold.t_stars)
    np.testing.assert_allclose(warm.opt_perfs, cold.opt_perfs, rtol=1e-9)
    assert warm.iterations < cold.iterations


# ---------------------------------------------------------------------------
# selector warm-state carry + fall-back paths
# ---------------------------------------------------------------------------


def _selector(engine="batched"):
    return BatchSizeSelector(
        candidates=(64, 128, 256, 512, 1024), ref_batch=64, engine=engine
    )


def test_selector_warm_sweep_matches_cold_plan():
    """A selector that warm-starts its resweep from the previous epoch's
    t_stars caches the same solutions a cold selector computes."""
    rng = np.random.default_rng(21)
    for seed in range(8):
        model = random_model(np.random.default_rng(seed), int(rng.integers(2, 24)))
        new = drifted(model, rel=1e-3, seed=seed)
        warm_sel = _selector()
        warm_sel._sweep(model)          # epoch k: cold
        warm_sel._sweep(new)            # epoch k+1: warm-started resweep
        cold_sel = _selector()
        cold_sel._sweep(new)            # fresh cold sweep of the same model
        assert warm_sel.warm_sweeps == 1 and warm_sel.cold_sweeps == 1
        for b in warm_sel.candidates:
            w, c = warm_sel._optperf_cache[b], cold_sel._optperf_cache[b]
            assert w.opt_perf == pytest.approx(c.opt_perf, rel=1e-9)
            assert w.bottleneck == c.bottleneck
            np.testing.assert_allclose(w.batches, c.batches, atol=1e-6)
        # select() emits identical plans on top of identical caches.
        assert warm_sel.select(new, 500.0)[:2][0] == cold_sel.select(new, 500.0)[0]


def test_selector_falls_back_cold_on_membership_change():
    rng = np.random.default_rng(31)
    sel = _selector()
    sel._sweep(random_model(rng, 8))
    assert sel.cold_sweeps == 1
    # Node joined/left: coefficient arrays change shape -> cold bracket.
    sel._sweep(random_model(rng, 9))
    assert sel.cold_sweeps == 2 and sel.warm_sweeps == 0


def test_selector_falls_back_cold_on_regime_change():
    rng = np.random.default_rng(37)
    model = random_model(rng, 8)
    sel = _selector()
    sel._sweep(model)
    # > warm_drift_limit relative coefficient change -> regime change.
    shifted = drifted(model, rel=1.0, seed=2)
    sel._sweep(shifted)
    assert sel.cold_sweeps == 2 and sel.warm_sweeps == 0
    # Small drift afterwards warm-starts again.
    sel._sweep(drifted(shifted, rel=1e-4, seed=3))
    assert sel.warm_sweeps == 1


def test_selector_invalidate_clears_warm_state():
    rng = np.random.default_rng(41)
    model = random_model(rng, 6)
    sel = _selector()
    sel._sweep(model)
    assert sel._warm_t_stars is not None
    sel.invalidate()
    assert sel._warm_t_stars is None and not sel._optperf_cache
    sel._sweep(model)
    assert sel.cold_sweeps == 2 and sel.warm_sweeps == 0


def test_scalar_engine_keeps_no_warm_state():
    rng = np.random.default_rng(43)
    sel = _selector(engine="scalar")
    sel._sweep(random_model(rng, 6))
    assert sel._warm_t_stars is None
    assert sel.warm_sweeps == 0 and sel.cold_sweeps == 0


# ---------------------------------------------------------------------------
# simulator drift vehicle
# ---------------------------------------------------------------------------


def test_simulated_cluster_perturbed():
    profiles, comm = cluster_B()
    sim = SimulatedCluster(profiles, comm, noise=0.01, seed=0)
    drift = sim.perturbed(1e-3, seed=5)
    assert drift.n == sim.n
    qs0 = np.array([p.q for p in sim.profiles])
    qs1 = np.array([p.q for p in drift.profiles])
    rel = np.abs(qs1 - qs0) / qs0
    assert np.all(rel > 0) and np.all(rel < 0.02)
    assert drift.comm == sim.comm                      # comm untouched by default
    drift2 = sim.perturbed(1e-3, seed=5, perturb_comm=True)
    assert drift2.comm.t_o != sim.comm.t_o
    # Reproducible: same seed, same drifted cluster.
    again = sim.perturbed(1e-3, seed=5)
    assert [p.q for p in again.profiles] == [p.q for p in drift.profiles]
    with pytest.raises(ValueError):
        sim.perturbed(-0.1)
    # Zero drift is the identity on coefficients.
    same = sim.perturbed(0.0)
    assert [p.q for p in same.profiles] == [p.q for p in sim.profiles]


def test_perturbed_cluster_warm_replan_parity():
    """End-to-end drift scenario: the optimal plan for a perturbed cluster is
    identical whether solved cold or warm-started from the pre-drift plan."""
    profiles, comm = cluster_C(12)
    sim = SimulatedCluster(profiles, comm, noise=0.0, seed=0)
    model = sim.true_model()
    cands = np.asarray([128.0, 256.0, 512.0, 1024.0, 2048.0])
    base = solve_optperf_batch(model, cands)
    for seed in range(5):
        new_model = sim.perturbed(5e-4, seed=seed).true_model()
        cold = solve_optperf_batch(new_model, cands)
        warm = solve_optperf_batch(new_model, cands, warm_start=base.t_stars)
        np.testing.assert_allclose(warm.opt_perfs, cold.opt_perfs, rtol=1e-9)
        np.testing.assert_allclose(warm.batches, cold.batches, atol=1e-6)

"""HLO analyzer tests: demonstrates the XLA cost_analysis while-body
undercount and validates the trip-count correction against hand counts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # JAX-compiling; excluded from the fast lane

from repro.launch.hlo_stats import analyze_hlo, raw_cost_analysis


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_plain_matmul_exact():
    a = jnp.ones((64, 128))
    b = jnp.ones((128, 32))
    comp = _compile(lambda a, b: a @ b, a, b)
    st = analyze_hlo(comp.as_text())
    assert st.matmul_flops == pytest.approx(2 * 64 * 128 * 32)


def test_scan_trip_correction():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=10)
        return c

    x = jnp.ones((128, 128))
    w = jnp.ones((128, 128))
    comp = _compile(f, x, w)
    # raw_cost_analysis: jax < 0.5 returns cost_analysis() as a 1-elem list.
    raw = raw_cost_analysis(comp)["flops"]
    st = analyze_hlo(comp.as_text())
    expected = 2 * 128**3 * 10
    # XLA counts the while body once...
    assert raw < expected / 5
    # ...the analyzer multiplies by the known trip count.
    assert st.matmul_flops == pytest.approx(expected, rel=1e-6)


def test_nested_scan_trip_correction():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None
        c, _ = jax.lax.scan(outer, x, None, length=3)
        return c

    x = jnp.ones((64, 64))
    w = jnp.ones((64, 64))
    comp = _compile(f, x, w)
    st = analyze_hlo(comp.as_text())
    assert st.matmul_flops == pytest.approx(2 * 64**3 * 12, rel=1e-6)


def test_grad_flops_roughly_3x_forward():
    w = jnp.ones((128, 128))
    x = jnp.ones((64, 128))

    def loss(w):
        return jnp.sum((x @ w) ** 2)

    fwd = analyze_hlo(_compile(loss, w).as_text()).matmul_flops
    bwd = analyze_hlo(_compile(jax.grad(loss), w).as_text()).matmul_flops
    assert 2.0 <= bwd / fwd <= 3.5


def test_bytes_accessed_reasonable():
    a = jnp.ones((1024, 1024), jnp.float32)
    comp = _compile(lambda a: a * 2.0 + 1.0, a)
    st = analyze_hlo(comp.as_text())
    nbytes = 1024 * 1024 * 4
    # read + write, fused: ~2x the array, allow slack for copies.
    assert nbytes * 1.5 <= st.bytes_accessed <= nbytes * 6


def test_dryrun_artifacts_have_collectives():
    """The committed dry-run artifacts (if present) expose per-kind
    collective bytes."""
    import glob
    import json
    import os

    files = glob.glob(os.path.join("artifacts", "dryrun", "*__train_4k__single.json"))
    if not files:
        pytest.skip("dry-run artifacts not generated yet")
    rec = json.load(open(files[0]))
    if rec.get("status") != "ok":
        pytest.skip("artifact not ok")
    assert rec["hlo"]["collective_bytes"] > 0
    assert "all-reduce" in rec["hlo"]["collective_by_kind"]

"""Checkpointing: pytree <-> .npz with keypath-string keys.

No orbax in this environment; .npz keeps things dependency-free and is
adequate for host-side checkpoints.  Arrays are gathered to host (works for
sharded arrays via np.asarray on addressable data in single-process runs).
bfloat16 has no numpy dtype — such leaves round-trip via a float32 view with
a dtype tag.

Integrity layer (PR 7): every checkpoint embeds a sha256 digest over its
canonicalized payload (sorted key / dtype / shape / raw bytes — the archive
container itself cannot be self-checksummed) plus a monotone generation
counter.  :func:`restore` verifies the digest and raises
:class:`CheckpointCorruptError` on mismatch; :class:`CheckpointManager`
keeps the last ``keep`` generations per job
(``<dir>/<name>.gen<NNNNNN>.ckpt.npz``) and rolls back to the newest valid
generation when the head is corrupt — the recovery path behind the
runtime's preemption/resume under ``CheckpointCorruption`` faults.
Checkpoints written by earlier releases (no digest) still restore.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = [
    "save",
    "restore",
    "LocalIO",
    "CheckpointCorruptError",
    "verify_checkpoint",
    "checkpoint_generation",
    "CheckpointManager",
]

_DTYPE_TAG = "__dtypes__"
_CHECKSUM_TAG = "__sha256__"
_GENERATION_TAG = "__generation__"


class CheckpointCorruptError(ValueError):
    """The checkpoint's payload does not match its embedded sha256 digest
    (or the archive is unreadable where a digest was expected)."""


class LocalIO:
    """Default checkpoint I/O: the local filesystem.

    ``save`` goes through this seam so fault injection (see
    :class:`repro.runtime.faults.FlakyCheckpointIO`) can make writes fail
    without monkeypatching builtins.  Any object with ``open(path, mode)``
    and ``replace(src, dst)`` works.
    """

    def open(self, path: str, mode: str):
        return open(path, mode)

    def replace(self, src: str, dst: str) -> None:
        os.replace(src, dst)


def _key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _payload_digest(flat: Dict[str, np.ndarray]) -> str:
    """sha256 over the canonicalized payload: sorted key, dtype, shape, raw
    bytes.  Self-contained (the digest entry itself is excluded by callers),
    so verification needs nothing beyond the archive."""
    h = hashlib.sha256()
    for k in sorted(flat):
        arr = np.ascontiguousarray(flat[k])
        h.update(k.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def _digest_array(digest: str) -> np.ndarray:
    return np.frombuffer(digest.encode(), dtype=np.uint8)


def save(
    path: str,
    tree: PyTree,
    *,
    io: Any = None,
    generation: Optional[int] = None,
) -> None:
    """Atomically write ``tree`` to ``path``.

    The payload lands in ``<path>.tmp`` first and is renamed over ``path``
    only once fully written, so a crash (or injected failure) mid-write can
    never leave a truncated archive where a valid previous checkpoint was.
    A sha256 digest over the canonical payload is embedded for load-time
    verification; ``generation`` (when given) stamps the monotone
    generation counter the :class:`CheckpointManager` rolls back across.
    """
    if io is None:
        io = LocalIO()
    flat = {}
    dtypes = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        k = _key(kp)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            dtypes[k] = "bfloat16"
            arr = arr.astype(np.float32)
        flat[k] = arr
    flat[_DTYPE_TAG] = np.frombuffer(
        json.dumps(dtypes).encode(), dtype=np.uint8
    )
    if generation is not None:
        flat[_GENERATION_TAG] = np.int64(generation)
    flat[_CHECKSUM_TAG] = _digest_array(_payload_digest(
        {k: v for k, v in flat.items() if k != _CHECKSUM_TAG}
    ))
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = f"{path}.tmp"
    try:
        with io.open(tmp, "wb") as f:
            np.savez(f, **flat)
        io.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            try:
                os.remove(tmp)
            except OSError:
                pass


def _verify_open(data) -> None:
    """Raise :class:`CheckpointCorruptError` when the open archive's payload
    does not match its embedded digest.  Archives without a digest (earlier
    releases) are accepted as-is."""
    if _CHECKSUM_TAG not in data.files:
        return
    stored = bytes(data[_CHECKSUM_TAG]).decode()
    flat = {k: data[k] for k in data.files if k != _CHECKSUM_TAG}
    actual = _payload_digest(flat)
    if actual != stored:
        raise CheckpointCorruptError(
            f"checkpoint payload digest mismatch: stored {stored[:12]}…, "
            f"computed {actual[:12]}…"
        )


def verify_checkpoint(path: str) -> bool:
    """True iff ``path`` is a readable checkpoint whose payload matches its
    embedded sha256 digest (archives without a digest pass, matching
    :func:`restore`'s backward compatibility)."""
    try:
        with np.load(path) as data:
            _verify_open(data)
        return True
    except Exception:
        return False


def checkpoint_generation(path: str) -> Optional[int]:
    """The generation counter stamped into ``path`` (None if unstamped or
    unreadable)."""
    try:
        with np.load(path) as data:
            if _GENERATION_TAG in data.files:
                return int(data[_GENERATION_TAG])
    except Exception:
        return None
    return None


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shapes validated, payload
    digest verified when present — :class:`CheckpointCorruptError` on
    mismatch)."""
    with np.load(path) as data:
        _verify_open(data)
        dtypes: Dict[str, str] = {}
        if _DTYPE_TAG in data:
            dtypes = json.loads(bytes(data[_DTYPE_TAG]).decode())
        leaves = []
        for kp, leaf in jax.tree_util.tree_flatten_with_path(like)[0]:
            k = _key(kp)
            if k not in data:
                raise KeyError(f"checkpoint missing leaf {k!r}")
            arr = data[k]
            want_shape = tuple(np.shape(leaf))
            if tuple(arr.shape) != want_shape:
                raise ValueError(
                    f"shape mismatch for {k}: checkpoint {arr.shape} vs model {want_shape}"
                )
            if dtypes.get(k) == "bfloat16":
                leaves.append(jnp.asarray(arr, jnp.bfloat16))
                continue
            # Leaves that were not JAX arrays when saved (plain NumPy
            # scalars/arrays — e.g. the GNS EMAs and stream counters of a
            # backend snapshot) keep their saved dtype: jnp.asarray would
            # silently downcast float64 under the default x64-disabled
            # config and break bit-exact resume.
            leaves.append(jnp.asarray(arr) if isinstance(leaf, jax.Array) else arr)
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Checksummed, versioned checkpoint generations with rollback.

    On-disk layout: ``<directory>/<name>.gen<NNNNNN>.ckpt.npz`` where
    ``NNNNNN`` is the zero-padded monotone generation counter (also stamped
    inside the archive).  ``save`` writes generation ``latest + 1`` and
    prunes to the newest ``keep`` generations; ``restore`` walks newest →
    oldest past corrupt/unreadable heads (each skip counted in
    ``rollbacks`` and recorded in ``corrupt_generations``) and raises
    :class:`CheckpointCorruptError` only when *no* generation verifies.
    The generation scan is on-disk state, so a fresh manager in a new
    process resumes the same sequence.
    """

    _GEN_RE = re.compile(r"\.gen(\d{6})\.ckpt\.npz$")

    def __init__(
        self,
        directory: str,
        name: str,
        *,
        keep: int = 3,
        io: Any = None,
    ) -> None:
        if keep < 1:
            raise ValueError("keep must be >= 1")
        self.directory = directory
        self.name = name
        self.keep = int(keep)
        self.io = io
        self.rollbacks = 0
        self.corrupt_generations: List[str] = []

    def _gen_path(self, gen: int) -> str:
        return os.path.join(self.directory, f"{self.name}.gen{gen:06d}.ckpt.npz")

    def generations(self) -> List[Tuple[int, str]]:
        """(generation, path) pairs on disk, ascending."""
        out: List[Tuple[int, str]] = []
        prefix = f"{self.name}.gen"
        try:
            entries = os.listdir(self.directory)
        except OSError:
            return out
        for fname in entries:
            if not fname.startswith(prefix):
                continue
            m = self._GEN_RE.search(fname)
            if m:
                out.append((int(m.group(1)), os.path.join(self.directory, fname)))
        return sorted(out)

    @property
    def latest_generation(self) -> int:
        gens = self.generations()
        return gens[-1][0] if gens else 0

    @property
    def latest_path(self) -> Optional[str]:
        gens = self.generations()
        return gens[-1][1] if gens else None

    def save(self, tree: PyTree, *, io: Any = None) -> str:
        """Write the next generation (atomic, checksummed) and prune to the
        retention bound.  A failed write leaves no file, so the counter
        does not advance — retries land on the same generation."""
        os.makedirs(self.directory, exist_ok=True)
        gen = self.latest_generation + 1
        path = self._gen_path(gen)
        save(path, tree, io=io if io is not None else self.io, generation=gen)
        self._prune()
        return path

    def _prune(self) -> None:
        gens = self.generations()
        for _, path in gens[: max(len(gens) - self.keep, 0)]:
            try:
                os.remove(path)
            except OSError:
                pass

    def restore(self, like: PyTree) -> Tuple[PyTree, int, str]:
        """Restore the newest generation that verifies, rolling back past
        corrupt heads.  Returns ``(tree, generation, path)``."""
        gens = self.generations()
        for gen, path in reversed(gens):
            try:
                tree = restore(path, like)
            except Exception:
                # Digest mismatch, unreadable zip, missing/mismatched
                # leaves: all mean "this generation cannot be trusted".
                self.rollbacks += 1
                self.corrupt_generations.append(path)
                continue
            return tree, gen, path
        raise CheckpointCorruptError(
            f"no valid checkpoint generation for {self.name!r} "
            f"in {self.directory} ({len(gens)} on disk, all corrupt)"
        )

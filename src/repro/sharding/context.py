"""Activation-sharding constraint hook.

Model code is mesh-agnostic; inside pjit, GSPMD occasionally loses the batch
or head sharding of activations across scan boundaries (observed: MLA
attention replicated over the 16-way model axis inside the kv-chunk scan —
a 16x FLOP bloat; MoE expert buffers replicated over data).  Models call
``constrain(x, logical_axes)`` at those points; it is a no-op unless a
`sharding_context(mesh, rules)` is active (the launcher activates it), so
single-device tests and the hetero trainer are unaffected.

Divisibility/duplicate-axis fallbacks come from MeshRules.spec, so a
constraint never produces an invalid spec (e.g. batch=1 stays replicated).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding

from repro.sharding.rules import MeshRules

__all__ = ["sharding_context", "constrain", "active_rules"]

_state = threading.local()


@contextlib.contextmanager
def sharding_context(mesh, rules: MeshRules):
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, rules)
    try:
        yield
    finally:
        _state.ctx = prev


def active_rules():
    """The MeshRules of the active sharding context, or None."""
    ctx = getattr(_state, "ctx", None)
    return ctx[1] if ctx else None


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(logical_axes) != x.ndim:
        raise ValueError(f"axes rank {len(logical_axes)} != tensor rank {x.ndim}")
    spec = rules.spec(logical_axes, x.shape, path="activation")
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

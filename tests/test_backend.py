"""ExecutionBackend acceptance tests: SimBackend replay parity with the
pre-refactor runtime (golden values), RealBackend gradient/GNS/clock
behaviour, preemption checkpoint/restore bit-exactness, the synthetic-trace
arrival/size distributions, and the make_policy deprecation shim."""
import math
import os
import warnings

import numpy as np
import pytest

from repro.core.perf_model import CommModel
from repro.core.scheduler import JobSpec, random_jobs
from repro.core.simulator import GPU_CATALOG
from repro.runtime import (
    ClusterRuntime,
    EpochRecord,
    JobState,
    RealBackendConfig,
    SimBackend,
    make_backend,
    replay,
    synthetic_trace,
)

N_NODES = 12


# ---------------------------------------------------------------------------
# SimBackend: bit-identical to the pre-refactor JobHandle.advance path
# ---------------------------------------------------------------------------

# Golden values captured by running the PR-4 (pre-ExecutionBackend) runtime
# on this exact scenario: synthetic_trace(3, 12, seed=0) replayed with
# policy="cannikin", epochs_per_event=2, steps=2, noise=0.01, seed=0.
_GOLDEN_AGG_GOODPUT = 2125.4784947969247
_GOLDEN_AGG_FRACTION = 1.0928105167204858
_GOLDEN_ASSIGNMENT = {"job1": (1, 5, 7, 8, 9, 10), "job2": (0, 2, 3, 4, 6)}
_GOLDEN_EPOCHS = {"job0": 6, "job1": 8, "job2": 6}
_GOLDEN_COUNTERS = {
    "allocations": 5,
    "warm_rounds": 28,
    "cold_rounds": 3,
    "solved_rows": 372,
    "cached_rows": 396,
}
_GOLDEN_SIM_TIME = {
    "job0": 2.780991958839693,
    "job1": 15.168174637445608,
    "job2": 33.567468442725044,
}
_GOLDEN_LAST_BATCHES = {
    "job0": (92, 102, 29, 33),
    "job1": (187, 188, 629, 175, 362, 507),
    "job2": (654, 559, 216, 147, 472),
}
_GOLDEN_GOODPUTS = {"job1": 1619.3591772804705, "job2": 506.11931751645443}


def test_sim_backend_replay_bit_identical_to_pre_refactor_golden():
    """A 2-epoch-per-event run through JobHandle.advance on SimBackend is
    bit-identical — allocations, counters, plans, simulated clocks — to the
    pre-refactor (controller + SimulatedCluster inlined) path."""
    trace, _ = synthetic_trace(3, N_NODES, seed=0)
    rep = replay(
        trace, N_NODES, policy="cannikin", epochs_per_event=2, steps=2,
        noise=0.01, seed=0,
    )
    s = rep.summary()
    assert s["aggregate_goodput"] == _GOLDEN_AGG_GOODPUT
    assert s["aggregate_fraction"] == _GOLDEN_AGG_FRACTION
    assert rep.runtime.allocation.assignment == _GOLDEN_ASSIGNMENT
    assert s["epochs"] == _GOLDEN_EPOCHS
    assert s["counters"] == _GOLDEN_COUNTERS
    for name, handle in rep.runtime.handles.items():
        assert handle.sim_time == _GOLDEN_SIM_TIME[name], name
        assert handle.last_plan.batches == _GOLDEN_LAST_BATCHES[name], name
        # Unified telemetry: every advanced epoch left an EpochRecord whose
        # plan/clock agree with the controller surface.
        assert len(handle.records) == handle.epochs_run
        assert all(r.backend == "sim" for r in handle.records)
        assert handle.records[-1].batches == handle.last_plan.batches
        assert handle.sim_time == pytest.approx(
            sum(r.epoch_seconds for r in handle.records)
        )
        assert math.isnan(handle.records[-1].mean_loss)  # sim: no gradients
    for name, g in _GOLDEN_GOODPUTS.items():
        assert rep.runtime.allocation.goodputs[name] == g


def test_sim_backend_direct_and_factory():
    spec = random_jobs(1, 4, seed=3)[0]
    backend = make_backend("sim", noise=0.0, seed=0)
    assert isinstance(backend, SimBackend)
    with pytest.raises(RuntimeError):
        backend.execute([2, 2], 1)
    backend.configure(spec, (0, 1, 2, 3), seed=5)
    result = backend.execute([4, 4, 4, 4], steps=3)
    assert len(result.measurements) == 3
    assert result.epoch_seconds > 0
    assert math.isnan(result.b_noise) and math.isnan(result.mean_loss)
    assert result.grad_observations == ()
    assert backend.snapshot() == {}  # nothing statistical to persist
    with pytest.raises(ValueError):
        make_backend("quantum")


def test_jobspec_backend_field_defaults_and_stamps():
    spec = random_jobs(1, 4, seed=1)[0]
    assert spec.backend == "sim"
    _, jobs = synthetic_trace(2, 6, seed=0, backend="real", total_batch=16)
    assert all(j.backend == "real" and j.total_batch == 16 for j in jobs)


class _FakeBackend:
    kind = "stale"

    def __init__(self):
        self.snaps = 0
        self.value = 0

    def configure(self, spec, node_ids, *, seed=0):
        pass

    def execute(self, batches, steps, *, lr_scale=1.0):
        raise NotImplementedError

    def snapshot(self):
        self.snaps += 1
        return {"v": self.value}

    def load_snapshot(self, state):
        self.value = state["v"]


def test_preempt_snapshots_only_on_running_edge():
    """A duplicate Preemption must not re-serialize post-preemption live
    state over the good snapshot (the checkpoint models a process that
    already died); the event counter still counts every event."""
    from repro.runtime.runtime import JobHandle

    spec = random_jobs(1, 2, seed=0)[0]
    h = JobHandle(spec)
    h.set_nodes((0, 1))
    assert h.state == JobState.RUNNING
    h.backend = _FakeBackend()
    h.backend.value = 42
    h.preempt()
    assert h.backend.snaps == 1
    assert h._snapshot == {"v": 42}
    h.backend.value = 0          # live state diverges after preemption
    h.preempt()                  # duplicate event
    assert h.backend.snaps == 1  # not re-snapshotted
    assert h._snapshot == {"v": 42}
    assert h.preemptions == 2    # events still counted (reconcile semantics)


def test_bind_backend_rebuilds_on_kind_change():
    """Node churn keeps the backend object (statistical state survives),
    but a spec naming a different backend kind gets a fresh engine."""
    from repro.runtime.runtime import JobHandle

    spec = random_jobs(1, 3, seed=0)[0]
    h = JobHandle(spec)
    h.set_nodes((0, 1))
    first = h.backend
    assert isinstance(first, SimBackend)
    h.set_nodes((0, 1, 2))       # churn: same engine, reconfigured
    assert h.backend is first
    h.backend = _FakeBackend()   # stale kind vs spec.backend == "sim"
    h.set_nodes((0, 1))
    assert isinstance(h.backend, SimBackend)
    assert h.backend is not first


# ---------------------------------------------------------------------------
# synthetic_trace: arrival processes / job-size distributions (satellite)
# ---------------------------------------------------------------------------


def test_synthetic_trace_default_unchanged():
    """The fixed trace stays the default and is byte-for-byte what it was:
    no RNG draw may leak into the default path."""
    trace, jobs = synthetic_trace(3, N_NODES, seed=0)
    times = [e.time for e in trace]
    assert times == [0.0, 1.0, 2.0, 3.0, 4.0]
    assert [j.total_batch for j in jobs] == [
        j.total_batch for j in random_jobs(3, N_NODES, 0)
    ]


def test_synthetic_trace_poisson_arrivals_seeded():
    t1, _ = synthetic_trace(4, 8, seed=3, arrival="poisson", departure=False,
                            node_leave=False)
    t2, _ = synthetic_trace(4, 8, seed=3, arrival="poisson", departure=False,
                            node_leave=False)
    times = [e.time for e in t1]
    assert times == [e.time for e in t2]          # seeded: reproducible
    assert times[0] == 0.0
    gaps = np.diff(times)
    assert (gaps > 0).all()                        # strictly increasing
    assert len(set(np.round(gaps, 12))) > 1        # not the fixed spacing
    t3, _ = synthetic_trace(4, 8, seed=4, arrival="poisson", departure=False,
                            node_leave=False)
    assert [e.time for e in t3] != times           # seed-sensitive
    with pytest.raises(ValueError):
        synthetic_trace(2, 8, arrival="uniform")


def test_synthetic_trace_lognormal_sizes_heavy_tailed():
    _, fixed = synthetic_trace(16, 8, seed=5, departure=False, node_leave=False)
    _, heavy = synthetic_trace(16, 8, seed=5, departure=False, node_leave=False,
                               size_dist="lognormal", size_sigma=1.0)
    assert [j.name for j in heavy] == [j.name for j in fixed]
    sizes = np.array([j.total_batch for j in heavy], dtype=float)
    assert (sizes >= np.array([j.ref_batch for j in heavy])).all()
    # Heavy tail: the multiplicative factors really spread (not all ~1).
    factors = sizes / np.array([j.total_batch for j in fixed], dtype=float)
    assert factors.max() / factors.min() > 3.0
    # Reproducible per seed.
    _, heavy2 = synthetic_trace(16, 8, seed=5, departure=False, node_leave=False,
                                size_dist="lognormal", size_sigma=1.0)
    assert [j.total_batch for j in heavy2] == [j.total_batch for j in heavy]
    with pytest.raises(ValueError):
        synthetic_trace(2, 8, size_dist="pareto")


def test_synthetic_trace_poisson_replays_through_runtime():
    trace, jobs = synthetic_trace(
        3, N_NODES, seed=2, arrival="poisson", size_dist="lognormal",
        size_sigma=0.8,
    )
    rep = replay(trace, N_NODES, policy="cannikin", epochs_per_event=1, steps=2)
    assert rep.aggregate_goodput > 0
    assert rep.runtime.handles[jobs[0].name].state == JobState.DONE


# ---------------------------------------------------------------------------
# make_policy deprecation shim (satellite)
# ---------------------------------------------------------------------------


def test_launch_make_policy_emits_deprecation_warning():
    from repro.launch.train import make_policy
    from repro.core.controller import CannikinController

    with pytest.deprecated_call(match="make_partition_policy"):
        policy = make_policy(
            "cannikin", 4, candidates=[32, 64], ref_batch=32, adaptive=True
        )
    assert isinstance(policy, CannikinController)
    # The replacement factory itself must stay warning-free.
    from repro.runtime import make_partition_policy

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        make_partition_policy("cannikin", 4, candidates=[32], ref_batch=32)


# ---------------------------------------------------------------------------
# RealBackend (slow lane: compiles JAX steps)
# ---------------------------------------------------------------------------


def _tiny_real_spec(total_batch=12, backend="real"):
    """3 heterogeneous nodes, CPU-sized batches."""
    models = tuple(
        GPU_CATALOG[name].model() for name in ("a100", "v100", "rtx6000")
    )
    return JobSpec(
        name="rj",
        node_models=models,
        comm=CommModel(t_o=0.04, t_u=0.008, gamma=0.15),
        total_batch=total_batch,
        b_noise=500.0,
        ref_batch=total_batch,
        backend=backend,
    )


def _real_config():
    return RealBackendConfig(arch="olmo-1b", seq_len=16, lr=0.3)


@pytest.mark.slow
def test_real_backend_tiny_dense_losses_gns_and_clock():
    """RealBackend on a tiny dense model: finite decreasing-ish losses, a
    positive b_noise from real gradient square-norms, and a monotone
    simulated clock."""
    pytest.importorskip("jax")
    from repro.core.controller import CannikinController
    from repro.runtime import EpochLoop

    spec = _tiny_real_spec()
    backend = _real_config().build(noise=0.0, seed=0)
    backend.configure(spec, (0, 1, 2), seed=1)
    ctrl = CannikinController(
        3, batch_candidates=[12, 24], ref_batch=12, adaptive=True
    )
    loop = EpochLoop(ctrl, backend, steps_per_epoch=2)
    records = loop.run(4)
    assert len(records) == 4
    assert all(isinstance(r, EpochRecord) and r.backend == "real" for r in records)
    assert all(np.isfinite(r.mean_loss) for r in records)
    assert records[-1].mean_loss < records[0].mean_loss
    # Theorem-4.1 tracking: both the backend tracker and the controller saw
    # real gradient telemetry.
    assert backend.gns.count > 0 and backend.gns.b_noise > 0
    assert np.isfinite(backend.gns.b_noise)
    assert ctrl.gns.count > 0 and records[-1].b_noise > 0
    # Monotone simulated clock.
    clocks = np.cumsum([r.epoch_seconds for r in records])
    assert (np.diff(clocks) > 0).all()
    assert backend.sim_time == pytest.approx(clocks[-1])
    assert backend.steps_done == 8


@pytest.mark.slow
def test_real_backend_checkpoint_roundtrip_bit_exact(tmp_path):
    """snapshot → file → load_snapshot restores params/opt-state/GNS/stream
    counters bit-exactly even after the live state was scrambled."""
    pytest.importorskip("jax")
    import jax

    from repro.core.gns import GNSState

    spec = _tiny_real_spec()
    backend = _real_config().build(noise=0.0, seed=0)
    backend.configure(spec, (0, 1, 2), seed=1)
    backend.execute([4, 4, 4], steps=2)
    path = os.path.join(tmp_path, "ck.npz")
    backend.checkpoint(path)
    want_params = jax.tree_util.tree_leaves(backend.params)
    want_gns, want_steps, want_sim = backend.gns, backend.steps_done, backend.sim_time

    backend.params = jax.tree_util.tree_map(lambda x: x + 1.0, backend.params)
    backend.gns = GNSState()
    backend.steps_done = 999
    backend.sim_time = 0.0
    backend.restore(path)

    got_params = jax.tree_util.tree_leaves(backend.params)
    for a, b in zip(want_params, got_params):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert backend.gns == want_gns
    assert backend.steps_done == want_steps
    assert backend.sim_time == want_sim


def _drive(preempt: bool, ckpt_dir, scramble: bool):
    rt = ClusterRuntime(
        3, policy="cannikin", seed=0,
        real_backend=_real_config(),
        checkpoint_dir=str(ckpt_dir) if preempt else None,
    )
    spec = _tiny_real_spec()
    handle = rt.submit(spec, at=0.0)
    rt.run()
    rt.advance(epochs=2, steps=2)
    if preempt:
        import jax

        from repro.core.gns import GNSState

        rt.preempt(spec.name, at=1.0)
        rt.run()
        assert handle.state == JobState.PREEMPTED
        assert handle.checkpoint_path is not None
        assert os.path.exists(handle.checkpoint_path)
        if scramble:
            # The in-process state is clobbered: only the checkpoint can
            # make resume correct.
            handle.backend.params = jax.tree_util.tree_map(
                lambda x: x * 0.0, handle.backend.params
            )
            handle.backend.gns = GNSState()
            handle.backend.steps_done = 0
        rt.submit(spec, at=2.0)  # JobCompletion-free resume
        rt.run()
        assert handle.state == JobState.RUNNING
    rt.advance(epochs=2, steps=2)
    return handle


@pytest.mark.slow
def test_runtime_preemption_checkpoint_restore_bit_exact(tmp_path):
    """Preemption → resume on RealBackend restores params/opt-state/GNS
    state from the checkpoint file bit-exactly: the preempted-and-resumed
    run finishes with the same losses and parameters as an unpreempted run
    with the same seed and plans — even though the live backend state was
    zeroed between preempt and resume."""
    pytest.importorskip("jax")
    import jax

    plain = _drive(preempt=False, ckpt_dir=tmp_path, scramble=False)
    resumed = _drive(preempt=True, ckpt_dir=tmp_path, scramble=True)

    assert plain.epochs_run == resumed.epochs_run == 4
    assert resumed.preemptions == 1
    # Same plans on both sides (single job -> full cluster both times).
    assert [r.batches for r in plain.records] == [
        r.batches for r in resumed.records
    ]
    # Same final losses, bit for bit.
    assert [r.mean_loss for r in plain.records] == [
        r.mean_loss for r in resumed.records
    ]
    # Same final parameters and GNS state, bit for bit.
    for a, b in zip(
        jax.tree_util.tree_leaves(plain.backend.params),
        jax.tree_util.tree_leaves(resumed.backend.params),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert plain.backend.gns == resumed.backend.gns
    assert plain.backend.steps_done == resumed.backend.steps_done

"""Fault-tolerance layer: deterministic injection, detection, recovery.

Covers the PR-6 acceptance criteria: seeded chaos replays self-heal with
zero human-scripted recovery and are bit-identical across replays; the
quarantine state machine's backoff-doubling/flap transitions are pinned;
the idempotency guard, atomic checkpoint writes, flaky-I/O retry, and the
solver graceful-degradation chain each have direct regression tests.
"""
import gc
import json
import os

import numpy as np
import pytest

import repro.core.scheduler as sched_mod
from repro.core.scheduler import random_jobs
from repro.runtime import (
    FaultInjector,
    FaultPlan,
    FlakyCheckpointIO,
    FlakyCheckpoints,
    NodeCrash,
    Straggler,
    CannikinPolicy,
    HealthConfig,
    HealthMonitor,
    JobState,
    NodeState,
    CrashDetected,
    QuarantineNode,
    ReadmitNode,
    SimBackend,
    JobHandle,
    make_fault_plan,
    replay,
    synthetic_trace,
)
from repro.runtime.trace import Trace
from repro.train import checkpoint as ckpt

N_NODES = 12


# ---------------------------------------------------------------------------
# fault plans: seeded determinism
# ---------------------------------------------------------------------------


def test_fault_plan_seeded_and_named():
    assert FaultPlan.chaos(N_NODES, seed=0) == FaultPlan.chaos(N_NODES, seed=0)
    assert FaultPlan.chaos(N_NODES, seed=0) != FaultPlan.chaos(N_NODES, seed=3)
    assert make_fault_plan("none", N_NODES) is None
    assert make_fault_plan("chaos", N_NODES, seed=2) == FaultPlan.chaos(N_NODES, 2)
    assert make_fault_plan("chaos-small", N_NODES) == FaultPlan.chaos_small(N_NODES)
    with pytest.raises(ValueError):
        make_fault_plan("mayhem", N_NODES)
    with pytest.raises(ValueError):
        FaultPlan.chaos(2)
    counts = FaultPlan.chaos(N_NODES).counts()
    assert counts["crashes"] == 1 and counts["stragglers"] == 3


def test_injector_is_invisible_until_a_fault_fires():
    """perturb() returns the measurement stream unchanged (same objects)
    when no fault touches the epoch — the bit-identity guarantee."""
    spec = random_jobs(1, 4, seed=3)[0]
    plan = FaultPlan(crashes=(NodeCrash(node=1, at_epoch=5),))
    plain, faulted = SimBackend(noise=0.01), SimBackend(noise=0.01, injector=FaultInjector(plan))
    plain.configure(spec, (0, 1, 2, 3), seed=7)
    faulted.configure(spec, (0, 1, 2, 3), seed=7)
    a = plain.execute([4, 4, 4, 4], steps=3)      # injector epoch 0 < onset
    b = faulted.execute([4, 4, 4, 4], steps=3)
    assert a.epoch_seconds == b.epoch_seconds
    assert a.measurements == b.measurements


def test_injector_crash_and_straggler_perturbations():
    spec = random_jobs(1, 4, seed=3)[0]
    inj = FaultInjector(
        FaultPlan(
            crashes=(NodeCrash(node=2, at_epoch=1, stall=2.0),),
            stragglers=(Straggler(node=0, at_epoch=1, duration=1, slowdown=3.0),),
        )
    )
    backend = SimBackend(noise=0.0, injector=inj)
    backend.configure(spec, (0, 1, 2, 3), seed=7)
    clean = backend.execute([4, 4, 4, 4], steps=2)
    inj.begin_epoch(1)
    hit = backend.execute([4, 4, 4, 4], steps=2)
    for m in hit.measurements:
        assert m.observations[2] is None            # crashed: silent stop
        assert m.observations[0] is not None
    # Straggler scaled node 0's observed compute times ~3x.
    c0, h0 = clean.measurements[0].observations[0], hit.measurements[0].observations[0]
    assert h0.a_time == pytest.approx(3.0 * c0.a_time)
    assert hit.epoch_seconds > clean.epoch_seconds  # stall + slowdown cost
    kinds = {f["kind"] for f in inj.injected}
    assert kinds == {"crash", "straggler"}


# ---------------------------------------------------------------------------
# the quarantine state machine (pinned transitions)
# ---------------------------------------------------------------------------


def _cfg():
    return HealthConfig(
        suspect_epochs=2, crash_epochs=2, backoff_initial=2, probation_epochs=2
    )


def test_quarantine_backoff_doubling_readmission_and_flap():
    mon = HealthMonitor(_cfg())

    def epoch(e, obs):
        mon.observe_job("j", e, [0], [obs], [1.0])
        mon.tick(e)
        return mon.poll()

    assert epoch(0, 1.0) == []                      # baseline established
    assert epoch(1, 3.0) == []                      # breach 1 of 2
    acts = epoch(2, 3.0)                            # breach 2 -> quarantine
    assert acts == [QuarantineNode(epoch=2, node=0, job="j", backoff=2)]
    assert mon.state(0) == NodeState.QUARANTINED
    assert epoch(3, 1.0) == []                      # quarantined: not sampled
    acts = epoch(4, 1.0)                            # backoff expired
    assert acts == [ReadmitNode(epoch=4, node=0)]
    assert mon.state(0) == NodeState.PROBATION
    assert epoch(5, 1.0) == []                      # clean probation epoch 1
    acts = epoch(6, 3.0)                            # flap: breach in probation
    assert acts == [QuarantineNode(epoch=6, node=0, job="j", backoff=4)]
    assert mon.state(0) == NodeState.QUARANTINED    # re-quarantined instantly
    for e in (7, 8, 9):
        assert epoch(e, 1.0) == []                  # doubled backoff: 4 epochs
    assert epoch(10, 1.0) == [ReadmitNode(epoch=10, node=0)]
    assert epoch(11, 1.0) == []
    assert epoch(12, 1.0) == []                     # 2 clean epochs -> healthy
    assert mon.state(0) == NodeState.HEALTHY
    assert mon.transitions(0) == [
        (2, NodeState.QUARANTINED),
        (4, NodeState.PROBATION),
        (6, NodeState.QUARANTINED),
        (10, NodeState.PROBATION),
        (12, NodeState.HEALTHY),
    ]


def test_crash_detected_from_missing_observations():
    mon = HealthMonitor(_cfg())
    mon.observe_job("j", 0, [0, 1], [None, 1.0], [1.0, 1.0])
    mon.tick(0)
    assert mon.poll() == []                          # 1 missing epoch: not yet
    mon.observe_job("j", 1, [0, 1], [None, 1.0], [1.0, 1.0])
    mon.tick(1)
    assert mon.poll() == [CrashDetected(epoch=1, node=0, job="j")]
    assert mon.state(0) == NodeState.CRASHED
    assert mon.detections == [{"kind": "crash", "node": 0, "job": "j", "epoch": 1}]
    # Crashed is terminal: further silence emits nothing new.
    mon.observe_job("j", 2, [0, 1], [None, 1.0], [1.0, 1.0])
    mon.tick(2)
    assert mon.poll() == []


def test_single_noisy_epoch_does_not_quarantine():
    mon = HealthMonitor(_cfg())
    mon.observe_job("j", 0, [0], [1.0], [1.0])
    mon.observe_job("j", 1, [0], [2.5], [1.0])       # one bad epoch
    mon.observe_job("j", 2, [0], [1.0], [1.0])       # recovers
    mon.tick(2)
    assert mon.poll() == []
    assert mon.state(0) == NodeState.HEALTHY


# ---------------------------------------------------------------------------
# the chaos acceptance scenario
# ---------------------------------------------------------------------------


def _chaos_replay(tmp_path, *, epochs_per_event=6):
    trace, jobs = synthetic_trace(3, N_NODES, seed=0)
    rep = replay(
        trace, N_NODES, policy="cannikin", epochs_per_event=epochs_per_event,
        steps=2, noise=0.01, seed=0, faults=FaultPlan.chaos(N_NODES, seed=0),
        checkpoint_dir=str(tmp_path),
    )
    return rep, jobs


def test_chaos_trace_self_heals_with_zero_scripted_recovery(tmp_path):
    rep, jobs = _chaos_replay(tmp_path)
    rt = rep.runtime
    plan = rt.injector.plan
    # Every job completes or is still training; nothing was lost.
    for name, state in rep.job_states.items():
        assert state in (JobState.DONE, JobState.RUNNING), (name, state)
    assert rep.job_states[jobs[0].name] == JobState.DONE

    # The crash was detected within 2 epochs of onset...
    crash = plan.crashes[0]
    det = [d for d in rt.health.detections if d["kind"] == "crash"]
    assert len(det) == 1 and det[0]["node"] == crash.node
    assert det[0]["epoch"] - crash.at_epoch <= 2
    # ...and recovered through the Preemption checkpoint path: the victim
    # was preempted, resubmitted, and resumed.
    rec = [r for r in rt.recovery_log if r["action"] == "crash_recover"]
    assert len(rec) == 1 and rec[0]["node"] == crash.node
    for victim in rec[0]["jobs"]:
        h = rt.handles[victim]
        assert h.preemptions >= 1
        assert h.state in (JobState.RUNNING, JobState.DONE)
        assert h.epochs_run > 0
    # The crashed node is masked out of every later allocation.
    assert crash.node in rt.down_nodes
    for ids in rt.allocation.assignment.values():
        assert crash.node not in ids

    # The straggler was quarantined and re-admitted.
    straggler = plan.stragglers[0]
    q = [
        d for d in rt.health.detections
        if d["kind"] == "quarantine" and d["node"] == straggler.node
    ]
    assert q and q[0]["epoch"] >= straggler.at_epoch
    assert q[0]["epoch"] - straggler.at_epoch <= 2
    readmits = [
        r for r in rt.recovery_log
        if r["action"] == "readmit" and r["node"] == straggler.node
    ]
    assert readmits, "straggler never re-admitted"
    assert rt.health.state(straggler.node) in (
        NodeState.HEALTHY, NodeState.PROBATION
    )

    # Telemetry surfaces the whole story.
    telemetry = rt.fault_telemetry()
    assert telemetry["detected"]["crash"] == 1
    assert telemetry["detected"]["quarantine"] >= 1
    assert telemetry["detection_latency_epochs"] <= 2
    assert telemetry["mttr_epochs"] is not None
    assert rep.goodput_retention is not None and 0 < rep.goodput_retention <= 1
    assert rep.summary()["faults"]["goodput_retention"] == rep.goodput_retention


def test_chaos_replay_bit_identical_across_replays(tmp_path):
    a, _ = _chaos_replay(tmp_path / "a", epochs_per_event=4)
    b, _ = _chaos_replay(tmp_path / "b", epochs_per_event=4)
    sa = json.dumps(a.summary(), sort_keys=True, default=str)
    sb = json.dumps(b.summary(), sort_keys=True, default=str)
    assert sa == sb
    assert a.runtime.health.detections == b.runtime.health.detections
    assert a.runtime.injector.injected == b.runtime.injector.injected
    assert a.runtime.recovery_log == b.runtime.recovery_log


def test_no_faults_health_enabled_is_observation_only():
    """With nothing injected the monitor must change nothing: allocations,
    epochs, counters all bit-identical to a monitor-free replay."""
    trace, _ = synthetic_trace(3, N_NODES, seed=0)
    plain = replay(trace, N_NODES, policy="cannikin", epochs_per_event=2,
                   steps=2, noise=0.01, seed=0)
    mon = replay(trace, N_NODES, policy="cannikin", epochs_per_event=2,
                 steps=2, noise=0.01, seed=0, health=True)
    s_plain, s_mon = plain.summary(), mon.summary()
    faults = s_mon.pop("faults")
    assert s_mon == s_plain
    assert mon.runtime.health.detections == []
    assert faults["detected"] == {
        "crash": 0, "quarantine": 0, "drift": 0, "numeric": 0,
    }


# ---------------------------------------------------------------------------
# idempotency guard
# ---------------------------------------------------------------------------


def _leave_trace(leaves):
    trace, _ = synthetic_trace(3, N_NODES, seed=0, node_leave=False)
    t = Trace(list(trace.events))
    at = 10.0
    for nodes in leaves:
        t.node_leave(nodes, at=at)
        at += 1.0
    return t


def test_doubled_node_leave_is_counted_noop():
    single = replay(_leave_trace([[7]]), N_NODES, policy="cannikin")
    doubled = replay(_leave_trace([[7], [7]]), N_NODES, policy="cannikin")
    assert doubled.runtime.allocation.assignment == single.runtime.allocation.assignment
    assert doubled.runtime.allocation.goodputs == single.runtime.allocation.goodputs
    assert doubled.runtime.counters() == single.runtime.counters()
    assert doubled.runtime.noop_events == 1
    assert single.runtime.noop_events == 0
    assert doubled.runtime.down_nodes == {7}


def test_unknown_node_leave_and_join_are_counted_noops():
    rep = replay(_leave_trace([[99]]), N_NODES, policy="cannikin")
    rt = rep.runtime
    assert rt.noop_events == 1
    assert rt.down_nodes == set()
    baseline = replay(_leave_trace([]), N_NODES, policy="cannikin")
    assert rt.allocation.assignment == baseline.runtime.allocation.assignment

    rt.node_join([99])     # unknown id
    rt.node_join([3])      # known but not down
    rt.run()
    assert rt.noop_events == 3
    assert rt.allocation.assignment == baseline.runtime.allocation.assignment


def test_partial_leave_applies_fresh_ids_only():
    """A leave naming one fresh and one stale id applies the fresh id and
    counts the event as a partial no-op."""
    rep = replay(_leave_trace([[7], [7, 8]]), N_NODES, policy="cannikin")
    clean = replay(_leave_trace([[7], [8]]), N_NODES, policy="cannikin")
    assert rep.runtime.down_nodes == {7, 8}
    assert rep.runtime.noop_events == 1
    assert rep.runtime.allocation.assignment == clean.runtime.allocation.assignment


# ---------------------------------------------------------------------------
# atomic checkpoints + the flaky I/O seam
# ---------------------------------------------------------------------------


class _TornFile:
    """File wrapper that dies once, partway through the ``budget``-th
    written byte.  It stays open (and working) after the trip so numpy's
    ZipFile destructor can clean up without a second error."""

    def __init__(self, f, budget):
        self._f = f
        self._budget = budget
        self._tripped = False

    def write(self, data):
        if not self._tripped and self._budget - len(data) <= 0:
            self._tripped = True
            self._f.write(data[: max(self._budget, 0)])  # the torn half
            raise OSError("disk died mid-write")
        self._budget -= len(data)
        return self._f.write(data)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def __getattr__(self, name):
        return getattr(self._f, name)


class _TornIO:
    def __init__(self, budget):
        self.budget = budget
        self.files = []

    def open(self, path, mode):
        f = _TornFile(open(path, mode), self.budget)
        self.files.append(f)
        return f

    def replace(self, src, dst):
        os.replace(src, dst)

    def close_all(self):
        for f in self.files:
            f._f.close()


def test_checkpoint_write_is_atomic_under_torn_write(tmp_path):
    path = str(tmp_path / "job.ckpt.npz")
    good = {"w": np.arange(4, dtype=np.float32), "step": np.int64(7)}
    ckpt.save(path, good)
    like = {"w": np.zeros(4, dtype=np.float32), "step": np.int64(0)}
    before = ckpt.restore(path, like)

    io = _TornIO(budget=64)
    with pytest.raises(OSError):
        ckpt.save(path, {"w": np.full(4, 9.0, np.float32), "step": np.int64(8)},
                  io=io)
    gc.collect()          # drain numpy's ZipFile finalizer deterministically
    io.close_all()
    # The torn write never touched the real file and left no tmp litter.
    assert not os.path.exists(path + ".tmp")
    after = ckpt.restore(path, like)
    np.testing.assert_array_equal(after["w"], before["w"])
    assert after["step"] == before["step"] == 7


class _StatefulBackend:
    """Minimal backend with a real (non-empty) snapshot, for exercising
    the checkpoint retry path without a full RealBackend."""

    kind = "sim"

    def __init__(self):
        self.state = {"w": np.arange(3, dtype=np.float32)}
        self.loads = 0

    def snapshot(self):
        return dict(self.state)

    def load_snapshot(self, state):
        self.state = dict(state)
        self.loads += 1


def _handle_with_flaky_io(tmp_path, failures):
    spec = random_jobs(1, 4, seed=0)[0]
    inj = FaultInjector(
        FaultPlan(flaky_checkpoints=FlakyCheckpoints(failures=failures))
    )
    handle = JobHandle(spec, checkpoint_dir=str(tmp_path), injector=inj)
    handle.backend = _StatefulBackend()
    handle.state = JobState.RUNNING
    handle.nodes = (0, 1)
    return handle, inj


def test_flaky_checkpoint_write_retries_then_succeeds(tmp_path):
    handle, inj = _handle_with_flaky_io(tmp_path, failures=1)
    handle.preempt()
    assert handle.ckpt_write_failures == 1           # first attempt failed
    assert handle.ckpt_fallbacks == 0
    assert handle.checkpoint_path is not None        # retry landed the file
    assert os.path.exists(handle.checkpoint_path)
    assert inj.checkpoint_io.failed == 1
    restored = ckpt.restore(
        handle.checkpoint_path, {"w": np.zeros(3, np.float32)}
    )
    np.testing.assert_array_equal(restored["w"], np.arange(3, dtype=np.float32))


def test_flaky_checkpoint_exhaustion_falls_back_to_memory(tmp_path):
    handle, _ = _handle_with_flaky_io(tmp_path, failures=10)
    handle.preempt()
    assert handle.ckpt_write_failures == 3           # bounded retries
    assert handle.ckpt_fallbacks == 1
    assert handle.checkpoint_path is None            # no torn file to trust
    backend = handle.backend
    backend.state = {"w": np.zeros(3, np.float32)}   # diverge live state
    handle._restore_backend()                        # resume path
    assert backend.loads == 1
    np.testing.assert_array_equal(
        backend.state["w"], np.arange(3, dtype=np.float32)
    )
    assert handle.restores == 1


# ---------------------------------------------------------------------------
# solver graceful degradation
# ---------------------------------------------------------------------------


def test_engine_degradation_chain_jax_to_batched(monkeypatch):
    spec = random_jobs(1, 8, seed=0)[0]
    orig = sched_mod._allocate_arrays

    def boom_on_jax(jobs, n_nodes, engine, **kw):
        if engine == "jax":
            raise RuntimeError("injected xla hiccup")
        return orig(jobs, n_nodes, engine, **kw)

    monkeypatch.setattr(sched_mod, "_allocate_arrays", boom_on_jax)
    pol = CannikinPolicy(8, engine="jax")
    alloc = pol.add_job(spec)
    assert pol.scheduler.engine == "batched"         # one tier dropped
    assert pol.engine_degradations == 1
    assert alloc.assignment[spec.name]               # job still placed
    assert pol.counters()["engine_degradations"] == 1


def test_degradation_serves_last_known_good_when_all_engines_fail(monkeypatch):
    spec = random_jobs(1, 8, seed=0)[0]
    pol = CannikinPolicy(8, engine="batched")
    good = pol.add_job(spec)

    def boom(*a, **kw):
        raise RuntimeError("solver dead")

    monkeypatch.setattr(sched_mod, "_allocate_arrays", boom)
    monkeypatch.setattr(sched_mod, "_allocate_scalar", boom)
    served = pol.reallocate()
    assert served is good                            # last-known-good plan
    assert pol.last_known_good_served == 1
    assert pol.scheduler.engine == "scalar"          # chain fully walked


def test_degradation_chain_preserves_validation_errors():
    spec = random_jobs(1, 8, seed=0)[0]
    pol = CannikinPolicy(8, engine="batched")
    pol.add_job(spec)
    with pytest.raises(ValueError):
        pol.add_job(spec)                            # duplicate arrival
    with pytest.raises(KeyError):
        pol.remove_job("no-such-job")
    assert pol.engine_degradations == 0              # chain never fired

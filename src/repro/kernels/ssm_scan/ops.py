"""Public entry for the selective-scan kernel (pads T to chunk multiples)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ssm_scan.ssm_scan import ssm_scan_kernel


def ssm_scan(u, dt, b_t, c_t, log_a, *, chunk: int = 64, d_block: int = 512,
             interpret: bool = True):
    bsz, t, d = u.shape
    c = min(chunk, t)
    pad = (-t) % c
    if pad:
        z2 = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        u, dt, b_t, c_t = z2(u), z2(dt), z2(b_t), z2(c_t)
    y, h = ssm_scan_kernel(
        u, dt, b_t, c_t, log_a, chunk=c, d_block=d_block, interpret=interpret
    )
    return y[:, :t], h

"""Heterogeneity-aware multi-job scheduler (beyond-paper; the paper's §6
"Adapt to schedulers for heterogeneous clusters" future-work item).

Existing schedulers (Pollux, Optimus) allocate homogeneous slices per job;
Sia is heterogeneity-aware across jobs but keeps each job's allocation
homogeneous.  With Cannikin, a job runs *optimally on any heterogeneous
subset* — its goodput for an arbitrary node set is computable from the
per-node performance models.  That turns scheduling into: partition the
cluster's (heterogeneous) nodes among jobs to maximize aggregate
goodput-fraction.

`allocate` uses greedy marginal-gain assignment (submodular-style):
repeatedly give the next node to the job whose *relative* goodput gains the
most from it.  Each job's goodput for a candidate node set comes from the
OptPerf solver over that subset — the same machinery the controller uses,
so scheduler decisions and runtime behaviour cannot diverge.

The default ``engine="batched"`` evaluates *every* (job, candidate-node)
marginal goodput of a greedy round as one
:func:`~repro.core.optperf.solve_optperf_stacked` call: the per-job
coefficient arrays are gathered into a padded
:class:`~repro.core.perf_model.StackedClusterModel` (one row per pair, each
row carrying that job's comm model and total batch), so allocation costs
O(rounds) array passes instead of O(jobs x nodes x solver) Python-level
water-fills.  ``engine="scalar"`` keeps the original per-pair loop as the
cross-check oracle; the chosen job's goodput is re-solved scalar after every
round in both engines, so emitted allocations carry engine-identical
numbers.

This is intentionally a library (allocation policy + simulation harness),
not a daemon: launch integration would wrap `allocate` in a reconcile loop.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.goodput import statistical_efficiency
from repro.core.optperf import solve_optperf_stacked, solve_optperf_waterfill
from repro.core.perf_model import (
    ClusterPerfModel,
    CommModel,
    NodePerfModel,
    StackedClusterModel,
)

__all__ = ["JobSpec", "Allocation", "allocate", "aggregate_goodput", "random_jobs"]


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """A job's statistical state + per-node performance models.

    ``node_models[i]`` is THIS job's fitted model for cluster node i (compute
    coefficients are job-dependent; §4.2).  ``comm`` is the job's fitted
    communication model.
    """

    name: str
    node_models: Tuple[NodePerfModel, ...]   # indexed by cluster node id
    comm: CommModel
    total_batch: int
    b_noise: float
    ref_batch: int
    min_nodes: int = 1

    @functools.cached_property
    def full_model(self) -> ClusterPerfModel:
        """This job's model over the whole cluster; its cached ``coeffs`` are
        the gather source for the batched scheduler rows."""
        return ClusterPerfModel(nodes=self.node_models, comm=self.comm)

    @functools.cached_property
    def efficiency(self) -> float:
        return statistical_efficiency(self.b_noise, self.total_batch, self.ref_batch)

    def goodput(self, node_ids: Sequence[int]) -> float:
        if len(node_ids) < self.min_nodes:
            return 0.0
        model = ClusterPerfModel(
            nodes=tuple(self.node_models[i] for i in node_ids), comm=self.comm
        )
        try:
            sol = solve_optperf_waterfill(model, self.total_batch)
        except (ValueError, RuntimeError):
            return 0.0
        thr = self.total_batch / sol.opt_perf
        return thr * self.efficiency

    def solo_goodput(self) -> float:
        """Goodput with the whole cluster — the normalizer for fairness."""
        return self.goodput(tuple(range(len(self.node_models))))


@dataclasses.dataclass(frozen=True)
class Allocation:
    assignment: Dict[str, Tuple[int, ...]]   # job -> node ids
    goodputs: Dict[str, float]
    fractions: Dict[str, float]              # goodput / solo goodput

    @property
    def aggregate_fraction(self) -> float:
        return float(sum(self.fractions.values()))


def _batched_gains(
    jobs: Sequence[JobSpec],
    assign: Dict[str, List[int]],
    candidates: Sequence[int],
    current: Dict[str, float],
    solo: Dict[str, float],
    healthy: Dict[str, bool],
) -> np.ndarray:
    """Normalized marginal gains for every (job, candidate node) pair.

    Builds one padded :class:`StackedClusterModel` — row ``(ji, r)`` is job
    ``ji``'s current node set plus candidate ``candidates[r]``, gathered from
    the job's cached full-cluster coefficient arrays with one fancy index —
    and water-fills all rows simultaneously.  Jobs whose fitted model failed
    validation get goodput-0 rows directly (the scalar path's graceful 0.0)
    instead of poisoning the shared solve.  Returns gains shaped
    ``(len(jobs), len(candidates))``, laid out so that ``argmax`` tie-breaks
    in (job order, ascending node id) order, exactly like the scalar loop.
    """
    n_jobs = len(jobs)
    n_cand = len(candidates)
    cand_arr = np.asarray(candidates, dtype=np.intp)
    width = max(len(assign[j.name]) for j in jobs) + 1
    rows = n_jobs * n_cand
    alphas = np.ones((rows, width))
    cs = np.zeros((rows, width))
    betas = np.ones((rows, width))
    ds = np.zeros((rows, width))
    ks = np.ones((rows, width))
    ms = np.zeros((rows, width))
    mask = np.zeros((rows, width), dtype=bool)
    t_o = np.empty(rows)
    t_u = np.empty(rows)
    gamma = np.empty(rows)
    totals = np.empty(rows)
    viable = np.empty(rows, dtype=bool)
    for ji, job in enumerate(jobs):
        cur = np.asarray(assign[job.name], dtype=np.intp)
        m = cur.size
        sl = slice(ji * n_cand, (ji + 1) * n_cand)
        totals[sl] = job.total_batch
        if not healthy[job.name]:
            # Garbage-fit job (bad node fit or bad comm model): inert unit
            # rows — mask True and zeroed comm keep the stack valid — with
            # goodput forced to 0 below, same as JobSpec.goodput's graceful
            # degradation.
            t_o[sl] = 0.0
            t_u[sl] = 0.0
            gamma[sl] = 0.0
            mask[sl, 0] = True
            viable[sl] = False
            continue
        t_o[sl] = job.comm.t_o
        t_u[sl] = job.comm.t_u
        gamma[sl] = job.comm.gamma
        idx = np.empty((n_cand, m + 1), dtype=np.intp)
        idx[:, :m] = cur
        idx[:, m] = cand_arr
        co = job.full_model.coeffs
        alphas[sl, : m + 1] = co.alphas[idx]
        cs[sl, : m + 1] = co.cs[idx]
        betas[sl, : m + 1] = co.betas[idx]
        ds[sl, : m + 1] = co.ds[idx]
        ks[sl, : m + 1] = co.ks[idx]
        ms[sl, : m + 1] = co.ms[idx]
        mask[sl, : m + 1] = True
        viable[sl] = (m + 1) >= job.min_nodes
    stack = StackedClusterModel(
        alphas=alphas, cs=cs, betas=betas, ds=ds, ks=ks, ms=ms,
        t_o=t_o, t_u=t_u, gamma=gamma, mask=mask,
    )
    sol = solve_optperf_stacked(stack, totals)
    goodputs = np.where(viable, totals / sol.opt_perfs, 0.0)
    eff = np.repeat([j.efficiency for j in jobs], n_cand)
    goodputs = goodputs * eff
    cur_v = np.repeat([current[j.name] for j in jobs], n_cand)
    solo_v = np.repeat([solo[j.name] for j in jobs], n_cand)
    return ((goodputs - cur_v) / solo_v).reshape(n_jobs, n_cand)


def allocate(
    jobs: Sequence[JobSpec], n_nodes: int, *, engine: str = "batched"
) -> Allocation:
    """Greedy marginal-gain node assignment.

    Seeds every job with its single best node (by marginal goodput), then
    assigns remaining nodes to the job with the largest *normalized*
    marginal gain (gain / solo goodput) — normalization prevents one large
    job from starving small ones (the same normalization Pollux's fair
    goodput objective uses).

    ``engine="batched"`` (default) evaluates each round's marginal gains as
    one stacked water-fill; ``engine="scalar"`` is the per-pair loop oracle.
    Both iterate candidates in ascending node id and jobs in caller order,
    so tie-breaking matches across engines.
    """
    if engine not in ("batched", "scalar"):
        raise ValueError(f"unknown allocate engine {engine!r}")
    if not jobs:
        return Allocation({}, {}, {})
    remaining = set(range(n_nodes))
    assign: Dict[str, List[int]] = {j.name: [] for j in jobs}
    solo = {j.name: max(j.solo_goodput(), 1e-12) for j in jobs}
    current = {j.name: 0.0 for j in jobs}

    def model_ok(job: JobSpec) -> bool:
        try:
            job.full_model.validate()
            return True
        except ValueError:
            return False

    # Validated once up front: a single garbage-fit job must not force every
    # round of the batched engine through the scalar fallback.
    healthy = {j.name: model_ok(j) for j in jobs}

    def scalar_gain(job: JobSpec, node: int) -> float:
        g = job.goodput(tuple(assign[job.name] + [node]))
        return (g - current[job.name]) / solo[job.name]

    def round_gains(round_jobs: Sequence[JobSpec], candidates: List[int]) -> np.ndarray:
        if engine == "batched":
            try:
                return _batched_gains(
                    round_jobs, assign, candidates, current, solo, healthy
                )
            except (ValueError, RuntimeError):
                pass  # degenerate stack: fall back to the scalar oracle
        return np.array(
            [[scalar_gain(j, nid) for nid in candidates] for j in round_jobs]
        )

    def take(job: JobSpec, nid: int) -> None:
        assign[job.name].append(nid)
        # Chosen sets are always re-solved by the scalar path so emitted
        # goodputs are engine-identical.
        current[job.name] = job.goodput(tuple(assign[job.name]))
        remaining.discard(nid)

    # Seed round: each job (in order of scarcity) takes its best node.
    for job in sorted(jobs, key=lambda j: -j.min_nodes):
        if not remaining:
            break
        candidates = sorted(remaining)
        gains = round_gains([job], candidates)
        take(job, candidates[int(np.argmax(gains[0]))])

    # Greedy rounds: all (job, node) marginal gains per round in one pass.
    while remaining:
        candidates = sorted(remaining)
        gains = round_gains(jobs, candidates)
        flat = int(np.argmax(gains))
        ji, r = divmod(flat, len(candidates))
        if gains[ji, r] <= 0:
            break  # nobody benefits (comm-bound saturation)
        take(jobs[ji], candidates[r])

    goodputs = {name: current[name] for name in assign}
    fractions = {name: goodputs[name] / solo[name] for name in assign}
    return Allocation(
        assignment={k: tuple(sorted(v)) for k, v in assign.items()},
        goodputs=goodputs,
        fractions=fractions,
    )


def aggregate_goodput(jobs: Sequence[JobSpec], allocation: Allocation) -> float:
    return float(sum(allocation.goodputs.values()))


def random_jobs(n_jobs: int, n_nodes: int, seed: int = 42) -> List[JobSpec]:
    """Seeded random job mix over the GPU catalog — the shared scenario
    generator for the scheduler benchmark gates and the engine-parity tests
    (one source so both always exercise the same distribution)."""
    from repro.core.simulator import GPU_CATALOG  # local: keep import graph lean

    rng = np.random.default_rng(seed)
    names = list(GPU_CATALOG)
    jobs = []
    for j in range(n_jobs):
        models = tuple(
            GPU_CATALOG[names[int(rng.integers(len(names)))]]
            .scaled(float(rng.uniform(0.5, 2.0)))
            .model()
            for _ in range(n_nodes)
        )
        jobs.append(
            JobSpec(
                name=f"job{j}",
                node_models=models,
                comm=CommModel(
                    t_o=float(rng.uniform(0.01, 0.08)),
                    t_u=float(rng.uniform(0.002, 0.02)),
                    gamma=float(rng.uniform(0.05, 0.4)),
                ),
                total_batch=int(rng.choice([256, 512, 1024, 2048])),
                b_noise=float(rng.uniform(100, 5000)),
                ref_batch=64,
                min_nodes=int(rng.integers(1, 3)),
            )
        )
    return jobs

"""Allocation policies for the ClusterRuntime, plus the partition-policy
factory shared by the launch CLI, examples, and benchmarks.

Two distinct policy kinds live here:

* **Allocation policies** (the :class:`Policy` protocol) decide *which
  nodes each job gets*.  They see every cluster event and return a full
  :class:`~repro.core.scheduler.Allocation`, so all policies are
  comparable in one trace run:

  - ``cannikin``   — the paper-derived heterogeneity-aware greedy
    allocator, wrapped around the incremental
    :class:`~repro.core.scheduler.Scheduler` so every event is an
    incremental re-allocation (cached rows + warm bracket seeds), never a
    cold solve.
  - ``static``     — contiguous equal-size node blocks in arrival order
    (the classic static-partition cluster baseline).
  - ``fair-share`` — nodes dealt round-robin across jobs in arrival
    order, so every job gets an even slice of every speed tier (the
    quota-style fair share of heterogeneous capacity).

  The baselines still *score* their assignments with each job's OptPerf
  goodput (via :meth:`JobSpec.goodput`), so aggregate goodput/fraction
  numbers are apples-to-apples across policies.

* **Partition policies** (:func:`make_partition_policy`) decide *how one
  job splits its batch across its nodes* — CannikinController vs the
  even/LB-BSP baselines of ``core/baselines.py``.  This is the factory
  ``launch/train.py`` and ``benchmarks/bench_adaptation.py`` share.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Protocol, Sequence, Set, Tuple, runtime_checkable

from repro.core.scheduler import Allocation, JobSpec, Scheduler

__all__ = [
    "Policy",
    "CannikinPolicy",
    "StaticPolicy",
    "FairSharePolicy",
    "POLICIES",
    "make_policy",
    "make_partition_policy",
    "drive_partition_policy",
]


@runtime_checkable
class Policy(Protocol):
    """What the ClusterRuntime needs from an allocation policy.

    Every mutator returns the resulting :class:`Allocation` so the
    runtime's reconcile loop is one call per event.  Implementations must
    be deterministic: replaying the same event sequence must reproduce the
    same allocations.
    """

    name: str
    n_nodes: int

    def add_job(self, spec: JobSpec) -> Allocation: ...

    def remove_job(self, name: str) -> Allocation: ...

    def update_job(self, spec: JobSpec) -> Allocation: ...

    def node_leave(self, node_ids: Sequence[int]) -> Allocation: ...

    def node_join(self, node_ids: Sequence[int]) -> Allocation: ...

    def reallocate(self) -> Allocation: ...

    @property
    def jobs(self) -> Tuple[JobSpec, ...]: ...


class CannikinPolicy:
    """The heterogeneity-aware allocator as a runtime policy.

    A thin veneer over the incremental :class:`Scheduler`: arrivals,
    departures, refits, and node churn all map onto its incremental
    entry points, so per-event cost is bounded by what actually changed
    (see the scheduler's ``warm_rounds``/``cached_rows`` counters, which
    this class surfaces via :meth:`counters`).
    """

    name = "cannikin"

    # Graceful degradation: when an engine's solver errors out mid-event
    # (an XLA hiccup on the jax path, say), the scheduler drops one tier
    # and retries — never letting one solver failure kill a job.
    _ENGINE_FALLBACK = {"jax": "batched", "batched": "scalar"}

    def __init__(self, n_nodes: int, *, engine: str = "batched", watchdog=None) -> None:
        self.n_nodes = n_nodes
        self.scheduler = Scheduler(n_nodes, engine=engine)
        self.engine_degradations = 0
        self.last_known_good_served = 0
        # Optional repro.runtime.watchdog.Watchdog: deadline-guards every
        # solve; a DeadlineExceeded (a RuntimeError) enters the same
        # degradation chain as a solver error, so a stalled solve costs one
        # engine tier, never a hung reconcile.
        self.watchdog = watchdog

    def _solve(self, op):
        """Run one scheduler entry point under the degradation chain.

        Validation errors (unknown job, duplicate arrival, bad node id:
        ``KeyError``/``ValueError``) propagate — those are caller bugs,
        not solver failures.  Anything else — including a watchdog
        ``DeadlineExceeded`` on a stalled solve — walks ``_ENGINE_FALLBACK``
        (jax → batched → scalar), re-solving from the scheduler's intact
        job/mask state; with every tier exhausted, the last-known-good
        allocation is served rather than raising mid-reconcile.
        """
        try:
            if self.watchdog is not None:
                return self.watchdog.guard_solve(op)
            return op()
        except (KeyError, ValueError):
            raise
        except Exception:
            while self.scheduler.engine in self._ENGINE_FALLBACK:
                self.scheduler.engine = self._ENGINE_FALLBACK[self.scheduler.engine]
                self.engine_degradations += 1
                try:
                    return self.scheduler.reallocate()
                except (KeyError, ValueError):
                    raise
                except Exception:
                    continue
            last_good = self.scheduler.allocation
            if last_good is not None:
                self.last_known_good_served += 1
                return last_good
            raise

    def add_job(self, spec: JobSpec) -> Allocation:
        return self._solve(lambda: self.scheduler.add_job(spec))

    def remove_job(self, name: str) -> Allocation:
        return self._solve(lambda: self.scheduler.remove_job(name))

    def update_job(self, spec: JobSpec) -> Allocation:
        return self._solve(lambda: self.scheduler.update_job(spec))

    def node_leave(self, node_ids: Sequence[int]) -> Allocation:
        return self._solve(lambda: self.scheduler.node_leave(node_ids))

    def node_join(self, node_ids: Sequence[int]) -> Allocation:
        return self._solve(lambda: self.scheduler.node_join(node_ids))

    def reallocate(self) -> Allocation:
        return self._solve(self.scheduler.reallocate)

    @property
    def jobs(self) -> Tuple[JobSpec, ...]:
        return self.scheduler.jobs

    def counters(self) -> Dict[str, int]:
        s = self.scheduler
        out = {
            "allocations": s.allocations,
            "warm_rounds": s.warm_rounds,
            "cold_rounds": s.cold_rounds,
            "solved_rows": s.solved_rows,
            "cached_rows": s.cached_rows,
        }
        # Degradation counters appear only once the chain actually fired,
        # keeping fault-free golden counter dicts unchanged.
        if self.engine_degradations:
            out["engine_degradations"] = self.engine_degradations
        if self.last_known_good_served:
            out["last_known_good_served"] = self.last_known_good_served
        if self.watchdog is not None and self.watchdog.solver_timeouts:
            out["solver_timeouts"] = self.watchdog.solver_timeouts
        return out


class _BaselinePolicy:
    """Shared bookkeeping for the non-adaptive allocation baselines.

    Subclasses implement :meth:`_assign` (names x available nodes ->
    assignment).  Goodputs/fractions come from each job's own OptPerf
    solve over its assigned set, so baseline allocations score on the
    same scale as Cannikin's.
    """

    name = "baseline"

    def __init__(self, n_nodes: int, **_: object) -> None:
        self.n_nodes = n_nodes
        self._jobs: Dict[str, JobSpec] = {}   # insertion order == arrival order
        self._down: Set[int] = set()
        self._solo: Dict[str, float] = {}

    # -- event surface ---------------------------------------------------

    def add_job(self, spec: JobSpec) -> Allocation:
        if spec.name in self._jobs:
            raise ValueError(f"job {spec.name!r} already scheduled")
        self._jobs[spec.name] = spec
        return self.reallocate()

    def remove_job(self, name: str) -> Allocation:
        if name not in self._jobs:
            raise KeyError(name)
        del self._jobs[name]
        self._solo.pop(name, None)
        return self.reallocate()

    def update_job(self, spec: JobSpec) -> Allocation:
        if spec.name not in self._jobs:
            raise KeyError(spec.name)
        self._jobs[spec.name] = spec
        self._solo.pop(spec.name, None)
        return self.reallocate()

    def node_leave(self, node_ids: Sequence[int]) -> Allocation:
        ids = {int(i) for i in node_ids}
        bad = [i for i in ids if not 0 <= i < self.n_nodes]
        if bad:
            raise ValueError(f"node ids out of range: {sorted(bad)}")
        self._down |= ids
        return self.reallocate()

    def node_join(self, node_ids: Sequence[int]) -> Allocation:
        self._down -= {int(i) for i in node_ids}
        return self.reallocate()

    @property
    def jobs(self) -> Tuple[JobSpec, ...]:
        return tuple(self._jobs.values())

    # -- allocation ------------------------------------------------------

    def _assign(
        self, names: List[str], avail: List[int]
    ) -> Dict[str, Tuple[int, ...]]:
        raise NotImplementedError

    def reallocate(self) -> Allocation:
        if not self._jobs:
            return Allocation({}, {}, {})
        avail = [n for n in range(self.n_nodes) if n not in self._down]
        assignment = self._assign(list(self._jobs), avail)
        goodputs, fractions = {}, {}
        for name, spec in self._jobs.items():
            ids = tuple(sorted(assignment.get(name, ())))
            assignment[name] = ids
            if name not in self._solo:
                self._solo[name] = max(spec.solo_goodput(), 1e-12)
            goodputs[name] = spec.goodput(ids) if ids else 0.0
            fractions[name] = goodputs[name] / self._solo[name]
        return Allocation(assignment=assignment, goodputs=goodputs, fractions=fractions)


class StaticPolicy(_BaselinePolicy):
    """Contiguous equal node blocks in arrival order.

    The classic statically-partitioned cluster: job i gets the i-th block
    of the available node list, block sizes as even as possible (earlier
    arrivals absorb the remainder).  Blind to heterogeneity — a block can
    land entirely on the slow tier.
    """

    name = "static"

    def _assign(self, names, avail):
        j = len(names)
        base, extra = divmod(len(avail), j)
        out: Dict[str, Tuple[int, ...]] = {}
        start = 0
        for i, name in enumerate(names):
            size = base + (1 if i < extra else 0)
            out[name] = tuple(avail[start : start + size])
            start += size
        return out


class FairSharePolicy(_BaselinePolicy):
    """Round-robin deal: node ``avail[i]`` goes to job ``i % J``.

    Every job gets an even *count* and — because consecutive node ids in
    the catalog clusters run fastest-to-slowest — an even slice of every
    speed tier: the quota-style fair share of heterogeneous capacity.
    Still blind to job-specific needs (batch size, comm sensitivity).
    """

    name = "fair-share"

    def _assign(self, names, avail):
        out: Dict[str, List[int]] = {name: [] for name in names}
        for i, nid in enumerate(avail):
            out[names[i % len(names)]].append(nid)
        return {name: tuple(ids) for name, ids in out.items()}


POLICIES = {
    "cannikin": CannikinPolicy,
    "static": StaticPolicy,
    "fair-share": FairSharePolicy,
}


def make_policy(name: str, n_nodes: int, *, engine: str = "batched") -> Policy:
    """Build an allocation policy by name (``cannikin``/``static``/
    ``fair-share``); ``engine`` selects the stacked-solver engine for the
    Cannikin policy (baselines score via the scalar path regardless)."""
    try:
        cls = POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown allocation policy {name!r}; choose from {sorted(POLICIES)}"
        ) from None
    return cls(n_nodes, engine=engine)


# ---------------------------------------------------------------------------
# Per-job batch-partition policies (single-job training loop)
# ---------------------------------------------------------------------------


def make_partition_policy(
    name: str,
    n_nodes: int,
    *,
    candidates: Sequence[int],
    ref_batch: int,
    adaptive: bool = True,
    sweep_engine: str = "batched",
    batch_policy: Optional[str] = None,
):
    """Build a batch-*partition* policy: how one job splits its global batch
    across its nodes each epoch.

    ``cannikin`` returns a :class:`~repro.core.controller.CannikinController`
    (OptPerf partition + optional adaptive total batch); ``even``/``ddp``/
    ``adaptdl`` the uniform split; ``lb-bsp`` the iterative Δ=5 tuner.
    ``batch_policy`` selects the controller's total-batch adaptation law
    from the :mod:`repro.core.batch_policy` registry (cannikin only).
    This is the single factory behind ``launch/train.py`` and the
    convergence/adaptation benchmarks.
    """
    from repro.core.baselines import EvenPartition, LBBSPPartition
    from repro.core.controller import CannikinController

    if name == "cannikin":
        return CannikinController(
            n_nodes,
            batch_candidates=candidates,
            ref_batch=ref_batch,
            adaptive=adaptive,
            sweep_engine=sweep_engine,
            batch_policy=batch_policy,
        )
    if name in ("even", "ddp", "adaptdl"):
        # AdaptDL's per-node split in heterogeneous clusters equals DDP's
        # (paper §5.2.2); its total-batch adaptivity is modeled by pairing
        # this partition with the Cannikin GNS engine in the convergence
        # benchmark.
        return EvenPartition(n_nodes)
    if name == "lb-bsp":
        return LBBSPPartition(n_nodes, delta=5)
    raise ValueError(f"unknown partition policy {name!r}")


def drive_partition_policy(policy, sim, total: int, epochs: int, *, steps: int = 8) -> List[float]:
    """Drive one partition policy against a :class:`SimulatedCluster` for
    ``epochs`` epochs; returns the per-epoch mean batch time.

    The canonical plan → measure → observe loop (shared by
    ``bench_adaptation`` and the examples so every driver exercises the
    identical protocol): Cannikin controllers plan and ingest epoch
    measurements; baselines just repartition from the last measurement.
    """
    from repro.core.controller import CannikinController

    times: List[float] = []
    last = None
    for epoch in range(epochs):
        if isinstance(policy, CannikinController):
            plan = policy.plan_epoch()
            batches = list(plan.batches)
        else:
            batches = policy.partition(total, epoch, last)
        t, ms = sim.run_epoch(batches, steps)
        last = ms[-1]
        if isinstance(policy, CannikinController):
            policy.observe_epoch(ms)
        times.append(t / steps)
    return times

"""Dense decoder-only transformer family.

Covers the assigned dense/VLM archs via config flags:
  * llama3-8b      — GQA, SwiGLU, RMSNorm, rope theta 5e5
  * minitron-4b    — GQA, squared-ReLU MLP (Nemotron lineage)
  * olmo-1b        — MHA, SwiGLU, *non-parametric* LayerNorm
  * internlm2-20b  — GQA, SwiGLU
  * chameleon-34b  — early-fusion VLM: plain token transformer over the
                     unified text+VQ-image vocabulary, with QK-norm
                     (the image tokenizer is a stub per the assignment —
                     tokens arrive pre-quantized)

Decode supports an optional sliding-window ring cache (``decode_window``) —
the sub-quadratic variant that qualifies dense archs for the long_500k shape.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Param

__all__ = [
    "DenseConfig",
    "schema",
    "init",
    "forward",
    "init_cache",
    "decode_step",
    "prefill",
]


@dataclasses.dataclass(frozen=True)
class DenseConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"            # rmsnorm | nonparam_ln
    act: str = "swiglu"              # swiglu | relu2 | gelu
    qk_norm: bool = False
    window: Optional[int] = None     # sliding-window attention (all layers)
    decode_window: Optional[int] = None  # ring-cache size for long-ctx decode
    max_full_cache: int = 32768      # use a full cache up to this seq length
    tie_embeddings: bool = False
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    kv_chunk: int = 2048

    @property
    def family(self) -> str:
        return "dense"


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------


def layer_schema(cfg: DenseConfig) -> Dict[str, Any]:
    d, h, kv, dh, ff = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff
    s: Dict[str, Any] = {
        "attn": {
            "wq": Param((d, h, dh), ("embed", "heads", None)),
            "wk": Param((d, kv, dh), ("embed", "kv_heads", None)),
            "wv": Param((d, kv, dh), ("embed", "kv_heads", None)),
            "wo": Param((h, dh, d), ("heads", None, "embed")),
        },
    }
    if cfg.qk_norm:
        s["attn"]["q_norm"] = Param((dh,), (None,), init="ones")
        s["attn"]["k_norm"] = Param((dh,), (None,), init="ones")
    if cfg.act == "swiglu":
        s["mlp"] = {
            "w_gate": Param((d, ff), ("embed", "ff")),
            "w_up": Param((d, ff), ("embed", "ff")),
            "w_down": Param((ff, d), ("ff", "embed")),
        }
    else:
        s["mlp"] = {
            "w_in": Param((d, ff), ("embed", "ff")),
            "w_down": Param((ff, d), ("ff", "embed")),
        }
    if cfg.norm == "rmsnorm":
        s["attn_norm"] = Param((d,), (None,), init="ones")
        s["mlp_norm"] = Param((d,), (None,), init="ones")
    return s


def schema(cfg: DenseConfig) -> Dict[str, Any]:
    s: Dict[str, Any] = {
        "embed": Param((cfg.vocab, cfg.d_model), ("vocab", None), init="embed"),
        "layers": common.stacked(layer_schema(cfg), cfg.n_layers),
    }
    if cfg.norm == "rmsnorm":
        s["final_norm"] = Param((cfg.d_model,), (None,), init="ones")
    if not cfg.tie_embeddings:
        s["lm_head"] = Param((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    return s


def init(rng: jax.Array, cfg: DenseConfig):
    return common.init_from_schema(rng, schema(cfg), cfg.param_dtype)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _norm(x: jax.Array, weight: Optional[jax.Array], cfg: DenseConfig) -> jax.Array:
    if cfg.norm == "rmsnorm":
        return common.rms_norm(x, weight)
    return common.layer_norm(x)  # non-parametric (OLMo)


def _mlp(lp: Dict[str, Any], x: jax.Array, cfg: DenseConfig) -> jax.Array:
    if cfg.act == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, lp["w_gate"])
        up = jnp.einsum("bsd,df->bsf", x, lp["w_up"])
        hidden = common.swiglu(gate, up)
    else:
        hidden = common.ACTIVATIONS[cfg.act](jnp.einsum("bsd,df->bsf", x, lp["w_in"]))
    return jnp.einsum("bsf,fd->bsd", hidden, lp["w_down"])


def _qkv(lp: Dict[str, Any], x: jax.Array, positions: jax.Array, cfg: DenseConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, lp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, lp["wv"])
    if cfg.qk_norm:
        q = common.rms_norm(q, lp["q_norm"])
        k = common.rms_norm(k, lp["k_norm"])
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _layer(lp: Dict[str, Any], x: jax.Array, positions: jax.Array, cfg: DenseConfig):
    h = _norm(x, lp.get("attn_norm"), cfg)
    q, k, v = _qkv(lp["attn"], h, positions, cfg)
    if cfg.window is not None:
        attn = common.local_window_attention(q, k, v, window=cfg.window)
    else:
        attn = common.full_attention(q, k, v, causal=True, kv_chunk=cfg.kv_chunk)
    x = x + jnp.einsum("bshk,hkd->bsd", attn, lp["attn"]["wo"])
    h = _norm(x, lp.get("mlp_norm"), cfg)
    x = x + _mlp(lp["mlp"], h, cfg)
    return x


def forward(params: Dict[str, Any], cfg: DenseConfig, tokens: jax.Array) -> jax.Array:
    """tokens (B, S) int32 -> logits (B, S, vocab)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    x = common.constrain(x, ("batch", None, None))
    positions = jnp.arange(s)

    def body(x, lp):
        return _layer(lp, x, positions, cfg), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["layers"])
    x = _norm(x, params.get("final_norm"), cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.compute_dtype)).astype(
        jnp.float32
    )


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def cache_length(cfg: DenseConfig, seq_len: int) -> int:
    """Full cache while it is affordable; ring (sliding-window) cache beyond
    ``max_full_cache`` when the config declares a decode window — the
    sub-quadratic dense-decode variant for long_500k."""
    if cfg.decode_window is not None and seq_len > cfg.max_full_cache:
        return min(cfg.decode_window, seq_len)
    return seq_len


def init_cache(cfg: DenseConfig, batch: int, seq_len: int, dtype=None):
    # Cache dtype must match the K/V the decode step produces (the config's
    # compute dtype) or dynamic_update_slice rejects the insert.
    if dtype is None:
        dtype = cfg.compute_dtype
    return common.make_kv_cache(
        cfg.n_layers, batch, cache_length(cfg, seq_len), cfg.n_kv_heads, cfg.head_dim, dtype
    )


def prefill(
    params: Dict[str, Any],
    cfg: DenseConfig,
    cache: Dict[str, jax.Array],
    tokens: jax.Array,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Fused full-sequence prefill: one forward pass over tokens (B, S) that
    also fills the KV cache at positions [0, S).

    Replaces the S-step single-token decode loop for prompt ingestion: the
    whole prompt goes through the batched attention path (one scan over
    layers instead of S of them).  Returns ``(logits (B, S, vocab), cache)``
    with ``cache["pos"] = S`` so ``decode_step`` continues at position S.

    Requires an *empty* full cache of length >= S (start-of-sequence
    semantics; ring caches must use the stepped loop — their physical layout
    depends on the write order).  Numerics: the chunked online-softmax
    prefill attention matches the stepped decode path to float tolerance,
    not bit-exactly.
    """
    b, s = tokens.shape
    length = cache["k"].shape[2]
    if cfg.decode_window is not None and length == cfg.decode_window and length < s:
        raise ValueError(
            "fused prefill needs a full-length cache; ring caches "
            f"(length {length} < prompt {s}) must use the stepped decode loop"
        )
    if length < s:
        raise ValueError(f"cache length {length} shorter than prompt {s}")
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    positions = jnp.arange(s)

    def body(x, layer):
        lp, k_cache, v_cache = layer
        h = _norm(x, lp.get("attn_norm"), cfg)
        q, k, v = _qkv(lp["attn"], h, positions, cfg)
        # K/V enter the cache post-RoPE, exactly as decode_step writes them.
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, 0, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, 0, axis=1)
        if cfg.window is not None:
            attn = common.local_window_attention(q, k, v, window=cfg.window)
        else:
            attn = common.full_attention(q, k, v, causal=True, kv_chunk=cfg.kv_chunk)
        x = x + jnp.einsum("bshk,hkd->bsd", attn, lp["attn"]["wo"])
        h = _norm(x, lp.get("mlp_norm"), cfg)
        x = x + _mlp(lp["mlp"], h, cfg)
        return x, (k_cache, v_cache)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"])
    )
    x = _norm(x, params.get("final_norm"), cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.compute_dtype)).astype(
        jnp.float32
    )
    return logits, {"k": new_k, "v": new_v, "pos": jnp.int32(s)}


def decode_step(
    params: Dict[str, Any],
    cfg: DenseConfig,
    cache: Dict[str, jax.Array],
    tokens: jax.Array,
    pos: jax.Array,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. tokens (B, 1); pos scalar int32 (current index).

    With a ring cache (decode_window set and smaller than the logical
    context), the physical insert index is pos mod window and the window
    constraint is enforced by the cache size itself.
    """
    b = tokens.shape[0]
    length = cache["k"].shape[2]
    ring = cfg.decode_window is not None and length == cfg.decode_window
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    positions = jnp.full((1,), pos, jnp.int32)

    def body(x, layer):
        lp, k_cache, v_cache = layer
        h = _norm(x, lp.get("attn_norm"), cfg)
        q, k, v = _qkv(lp["attn"], h, positions, cfg)
        idx = pos % length if ring else pos
        k_cache, v_cache = common.cache_update(k_cache, v_cache, k, v, idx)
        # Ring caches enforce the window by construction; full caches attend
        # to the whole context (cfg.window, if any, still applies).
        attn = common.decode_attention(
            q, k_cache, v_cache, pos=pos, window=None if ring else cfg.window
        )
        x = x + jnp.einsum("bshk,hkd->bsd", attn, lp["attn"]["wo"])
        h = _norm(x, lp.get("mlp_norm"), cfg)
        x = x + _mlp(lp["mlp"], h, cfg)
        return x, (k_cache, v_cache)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"])
    )
    x = _norm(x, params.get("final_norm"), cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.compute_dtype)).astype(
        jnp.float32
    )
    return logits, {"k": new_k, "v": new_v, "pos": pos + 1}

"""Optimizers (pure pytree implementations — no optax in this environment).

SGD+momentum and AdamW, plus LR schedules and the AdaScale/sqrt LR-scaling
hooks the paper's Table 4 workloads use.  All states are pytrees compatible
with pjit sharding (moments inherit the parameter PartitionSpecs; a ZeRO-1
wrapper for sharding moments over the data axis lives in launch/steps).

Mixed precision: parameters may be bf16; moments and the update math run in
float32; the update is cast back to the parameter dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = [
    "Optimizer",
    "sgd",
    "adamw",
    "cosine_schedule",
    "constant_schedule",
    "global_norm",
    "clip_by_global_norm",
]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """(init, update) pair.  update(grads, state, params, lr_scale) ->
    (new_params, new_state).  ``lr_scale`` is the Cannikin/AdaScale
    per-epoch multiplier."""

    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jax.Array], Tuple[PyTree, PyTree]]
    name: str = "optimizer"


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(tree: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda x: x * scale.astype(x.dtype), tree), norm


def constant_schedule(lr: float) -> Callable[[jax.Array], jax.Array]:
    return lambda step: jnp.float32(lr)


def cosine_schedule(
    lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def fn(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = step / max(warmup_steps, 1)
        frac = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.float32(lr) * jnp.where(step < warmup_steps, warm, cos)

    return fn


class SGDState(NamedTuple):
    momentum: PyTree
    step: jax.Array


def sgd(
    schedule: Callable[[jax.Array], jax.Array],
    momentum: float = 0.9,
    weight_decay: float = 0.0,
    max_grad_norm: Optional[float] = 1.0,
) -> Optimizer:
    def init(params: PyTree) -> SGDState:
        mom = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        return SGDState(momentum=mom, step=jnp.zeros((), jnp.int32))

    def update(grads, state: SGDState, params, lr_scale=1.0):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        lr = schedule(state.step) * lr_scale

        def upd(g, m, p):
            g32 = g.astype(jnp.float32)
            if weight_decay:
                g32 = g32 + weight_decay * p.astype(jnp.float32)
            m_new = momentum * m + g32
            p_new = p.astype(jnp.float32) - lr * m_new
            return p_new.astype(p.dtype), m_new

        flat = jax.tree_util.tree_map(upd, grads, state.momentum, params)
        new_params = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_mom = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, SGDState(momentum=new_mom, step=state.step + 1)

    return Optimizer(init=init, update=update, name="sgd")


class AdamWState(NamedTuple):
    m: PyTree
    v: PyTree
    step: jax.Array


def adamw(
    schedule: Callable[[jax.Array], jax.Array],
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: Optional[float] = 1.0,
) -> Optimizer:
    def init(params: PyTree) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            m=jax.tree_util.tree_map(zeros, params),
            v=jax.tree_util.tree_map(zeros, params),
            step=jnp.zeros((), jnp.int32),
        )

    def update(grads, state: AdamWState, params, lr_scale=1.0):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        lr = schedule(state.step) * lr_scale
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g32
            v_new = b2 * v + (1 - b2) * g32 * g32
            mh = m_new / bc1
            vh = v_new / bc2
            p32 = p.astype(jnp.float32)
            p_new = p32 - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p32)
            return p_new.astype(p.dtype), m_new, v_new

        flat = jax.tree_util.tree_map(upd, grads, state.m, state.v, params)
        take = lambda i: jax.tree_util.tree_map(
            lambda t: t[i], flat, is_leaf=lambda x: isinstance(x, tuple)
        )
        return take(0), AdamWState(m=take(1), v=take(2), step=step)

    return Optimizer(init=init, update=update, name="adamw")

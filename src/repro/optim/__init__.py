from repro.optim.optimizers import (
    Optimizer,
    adamw,
    clip_by_global_norm,
    constant_schedule,
    cosine_schedule,
    global_norm,
    sgd,
)

__all__ = [
    "Optimizer",
    "adamw",
    "sgd",
    "cosine_schedule",
    "constant_schedule",
    "global_norm",
    "clip_by_global_norm",
]
